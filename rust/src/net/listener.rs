//! TCP front end: bounded accept loop + per-connection handlers that
//! feed the batcher queue.
//!
//! Admission control happens at three gates, each of which answers
//! with an explicit [`ErrorCode::RetryAfter`] frame instead of
//! buffering unboundedly:
//!
//! 1. **connection cap** (`max_conns`) — refused at accept time;
//! 2. **per-connection pipeline cap** (`max_inflight`) — a client may
//!    pipeline requests, but only that many may be outstanding on one
//!    connection;
//! 3. **server-wide backlog cap** (`shed_after`) — total outstanding
//!    wire requests across all connections; the batcher queue's own
//!    `try_send` failure sheds the same way, so the server never
//!    blocks a connection thread on a full queue.
//!
//! Each connection is one thread running a poll loop: deliver any
//! completed replies, then read (with a short tick timeout) the first
//! byte of the next frame. The first-byte read doubles as the idle
//! detector — a connection with no traffic and no outstanding work
//! for longer than `read_timeout` is closed — while *mid-frame*
//! stalls are bounded separately inside the frame decoder (a peer
//! that sends half a header gets `BadFrame`/close, not a held thread).
//!
//! Observability: `net.accepted` / `net.shed` / `net.drained` /
//! `net.proto_errors` counters and a `net.frame_latency` histogram
//! (enqueue → reply written). The first protocol error on a
//! connection triggers a flight-recorder dump.

use std::io::{self, Read};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream,
               ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, SyncSender, TryRecvError, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::server::{self as srv, ScoreReject, ScoreRequest,
                                 ScoreResponse, ServerMsg, StatsRequest,
                                 UpdateRequest, UpdateResponse};
use crate::incremental::GraphDelta;
use crate::obs::flight;
use crate::obs::metrics::{Counter, Histogram, MetricsRegistry,
                          StatsSnapshot};
use crate::util::json::{self, Value};

use super::frame::{self, ErrorCode, Frame, FrameKind, Mode, WireError};

/// Poll tick for connection loops: first-byte read timeout and the
/// reply-flush cadence. Small enough that drain/stop are noticed
/// promptly, large enough to stay off the scheduler's back.
const TICK: Duration = Duration::from_millis(10);

/// Suggested client back-off carried in `RetryAfter` frames.
const RETRY_AFTER_MS: f64 = 50.0;

/// Front-end tuning knobs (see module docs for the three gates).
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Outstanding requests allowed per connection (pipelining cap).
    pub max_inflight: usize,
    /// Outstanding requests allowed server-wide before load-shedding.
    pub shed_after: usize,
    /// Idle limit: a connection with no frames and no outstanding
    /// work for this long is closed. Also bounds mid-frame stalls.
    pub read_timeout: Duration,
    /// Per-write socket timeout.
    pub write_timeout: Duration,
    /// Frame payload cap in bytes (declared lengths above this are
    /// rejected without reading the payload).
    pub max_payload: u32,
    /// Concurrent connection cap (each costs one thread).
    pub max_conns: usize,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            max_inflight: 32,
            shed_after: 256,
            read_timeout: Duration::from_secs(30),
            write_timeout: Duration::from_secs(10),
            max_payload: frame::DEFAULT_MAX_PAYLOAD,
            max_conns: 256,
        }
    }
}

/// State shared by the accept loop and every connection thread.
pub(super) struct Shared {
    pub(super) queue: SyncSender<ServerMsg>,
    pub(super) epoch: Arc<AtomicU64>,
    pub(super) registry: Arc<MetricsRegistry>,
    pub(super) cfg: NetConfig,
    pub(super) accepting: AtomicBool,
    pub(super) draining: AtomicBool,
    pub(super) stopped: AtomicBool,
    /// Server-wide outstanding wire requests (gate 3).
    pub(super) inflight: AtomicUsize,
    pub(super) active_conns: AtomicUsize,
    pub(super) accepted: Counter,
    pub(super) shed: Counter,
    pub(super) drained: Counter,
    pub(super) proto_errors: Counter,
    pub(super) frame_lat: Histogram,
}

/// Handle to a running TCP front end. Decoupled from
/// [`crate::coordinator::InferenceServer`] on purpose: `spawn` takes
/// the raw batcher queue + epoch cell, so conformance tests can stand
/// up a front end over a test-owned consumer and script the batcher
/// side deterministically.
pub struct NetServer {
    pub(super) shared: Arc<Shared>,
    pub(super) local: SocketAddr,
    pub(super) accept: Option<JoinHandle<()>>,
    pub(super) conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl NetServer {
    /// Bind `listen` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start accepting. `queue` is the batcher queue (normally
    /// [`InferenceServer::client`](crate::coordinator::InferenceServer::client)),
    /// `epoch` the live plan-epoch cell
    /// ([`InferenceServer::epoch_cell`](crate::coordinator::InferenceServer::epoch_cell)),
    /// `registry` where the `net.*` metrics land.
    pub fn spawn(listen: impl ToSocketAddrs, queue: SyncSender<ServerMsg>,
                 epoch: Arc<AtomicU64>, registry: Arc<MetricsRegistry>,
                 cfg: NetConfig) -> io::Result<NetServer> {
        let listener = TcpListener::bind(listen)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shared = Arc::new(Shared {
            accepted: registry.counter("net.accepted"),
            shed: registry.counter("net.shed"),
            drained: registry.counter("net.drained"),
            proto_errors: registry.counter("net.proto_errors"),
            frame_lat: registry.histogram("net.frame_latency"),
            queue,
            epoch,
            registry,
            cfg,
            accepting: AtomicBool::new(true),
            draining: AtomicBool::new(false),
            stopped: AtomicBool::new(false),
            inflight: AtomicUsize::new(0),
            active_conns: AtomicUsize::new(0),
        });
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> =
            Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let shared = shared.clone();
            let conns = conns.clone();
            std::thread::Builder::new()
                .name("net-accept".into())
                .spawn(move || accept_loop(listener, shared, conns))?
        };
        crate::obs_event!("net.listen", local.port() as u64);
        Ok(NetServer { shared, local, accept: Some(accept), conns })
    }

    /// The bound address (resolves `:0` to the real ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// Current server-wide outstanding wire requests.
    pub fn inflight(&self) -> usize {
        self.shared.inflight.load(Ordering::Acquire)
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>,
               conns: Arc<Mutex<Vec<JoinHandle<()>>>>) {
    loop {
        if shared.stopped.load(Ordering::Acquire)
            || !shared.accepting.load(Ordering::Acquire)
        {
            break;
        }
        match listener.accept() {
            Ok((stream, peer)) => {
                let active = shared.active_conns.load(Ordering::Acquire);
                if active >= shared.cfg.max_conns {
                    shared.shed.inc();
                    refuse(&shared, stream, ErrorCode::RetryAfter,
                           "connection limit reached");
                    continue;
                }
                shared.accepted.inc();
                shared.active_conns.fetch_add(1, Ordering::AcqRel);
                let sh = shared.clone();
                let handle = std::thread::Builder::new()
                    .name(format!("net-conn-{peer}"))
                    .spawn(move || {
                        handle_conn(&sh, stream);
                        sh.active_conns.fetch_sub(1, Ordering::AcqRel);
                    });
                match handle {
                    Ok(h) => {
                        let mut g = conns.lock().unwrap();
                        // Reap finished handles so the vec stays
                        // bounded by the live-connection count.
                        g.retain(|h| !h.is_finished());
                        g.push(h);
                    }
                    Err(_) => {
                        // Thread spawn failed (resource exhaustion):
                        // undo the accept accounting and shed.
                        shared.active_conns.fetch_sub(1, Ordering::AcqRel);
                        shared.shed.inc();
                    }
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

/// Best-effort single error frame to a connection refused at accept
/// time (the peer has not spoken yet, so binary mode is assumed).
fn refuse(shared: &Shared, stream: TcpStream, code: ErrorCode,
          message: &str) {
    let _ = stream.set_write_timeout(Some(shared.cfg.write_timeout));
    let epoch = shared.epoch.load(Ordering::Acquire);
    let f = Frame::error(0, epoch, code, message,
                         vec![("retry_after_ms",
                               json::num(RETRY_AFTER_MS))]);
    let _ = frame::write_frame(&mut &stream, &f, Mode::Binary);
    // An eager client may have pipelined a request already; close
    // without resetting so the refusal frame survives.
    let _ = stream.set_read_timeout(Some(TICK));
    graceful_close(&stream);
}

/// One outstanding wire request on a connection, awaiting its reply
/// from the batcher. `mode` remembers the encoding the request
/// arrived in so the reply matches it.
enum Pending {
    Score {
        id: u64,
        mode: Mode,
        submitted: Instant,
        rx: Receiver<ScoreResponse>,
    },
    Update {
        id: u64,
        mode: Mode,
        submitted: Instant,
        rx: Receiver<UpdateResponse>,
    },
    Stats {
        id: u64,
        mode: Mode,
        submitted: Instant,
        rx: Receiver<StatsSnapshot>,
    },
}

impl Pending {
    fn mode(&self) -> Mode {
        match self {
            Pending::Score { mode, .. }
            | Pending::Update { mode, .. }
            | Pending::Stats { mode, .. } => *mode,
        }
    }

    fn submitted(&self) -> Instant {
        match self {
            Pending::Score { submitted, .. }
            | Pending::Update { submitted, .. }
            | Pending::Stats { submitted, .. } => *submitted,
        }
    }
}

fn timeoutish(e: &io::Error) -> bool {
    matches!(e.kind(),
             io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

fn handle_conn(shared: &Shared, stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(TICK));
    let _ = stream.set_write_timeout(Some(shared.cfg.write_timeout));
    let mut pending: Vec<Pending> = Vec::new();
    let mut last_activity = Instant::now();
    let mut last_mode = Mode::Binary;
    let mut peer_closed = false;
    let mut flight_dumped = false;

    loop {
        if flush_pending(shared, &stream, &mut pending).is_err() {
            break;
        }
        if shared.stopped.load(Ordering::Acquire) {
            break;
        }
        if pending.is_empty()
            && (peer_closed || shared.draining.load(Ordering::Acquire))
        {
            break;
        }
        if peer_closed {
            // Nothing left to read; wait for outstanding replies.
            std::thread::sleep(Duration::from_millis(2));
            continue;
        }
        let mut b = [0u8; 1];
        match (&stream).read(&mut b) {
            Ok(0) => peer_closed = true,
            Ok(_) => {
                last_activity = Instant::now();
                match frame::read_frame_after(b[0], &mut &stream,
                                              shared.cfg.max_payload,
                                              shared.cfg.read_timeout) {
                    Ok((f, mode)) => {
                        last_mode = mode;
                        if !dispatch(shared, &stream, &mut pending, f,
                                     mode, &mut flight_dumped) {
                            break;
                        }
                    }
                    Err(e) => {
                        protocol_error(shared, &stream, last_mode, &e,
                                       &mut flight_dumped);
                        break;
                    }
                }
            }
            Err(e) if timeoutish(&e) => {
                if pending.is_empty()
                    && last_activity.elapsed() >= shared.cfg.read_timeout
                {
                    break;
                }
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => break,
        }
    }

    // Courtesy window: deliver replies that are already (or about to
    // be) computed, then release the connection's inflight slots so
    // the server-wide gauge does not leak.
    let deadline = Instant::now() + Duration::from_millis(200);
    while !pending.is_empty() && Instant::now() < deadline {
        if flush_pending(shared, &stream, &mut pending).is_err() {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    if !pending.is_empty() {
        shared.inflight.fetch_sub(pending.len(), Ordering::AcqRel);
    }
    graceful_close(&stream);
}

/// Close without an RST: send FIN first, then swallow whatever the
/// peer already had in flight. Dropping a socket with unread bytes in
/// its receive buffer resets the connection, which would destroy a
/// final error frame before the client gets to read it.
fn graceful_close(stream: &TcpStream) {
    let _ = stream.shutdown(Shutdown::Write);
    let mut scratch = [0u8; 4096];
    let mut swallowed = 0usize;
    loop {
        match (&stream).read(&mut scratch) {
            Ok(0) => break,
            Ok(n) => {
                swallowed += n;
                // A peer still firehosing gets the RST it asked for.
                if swallowed > 256 * 1024 {
                    break;
                }
            }
            // WouldBlock after one read-timeout tick, or a hard
            // error: the buffer is empty, safe to drop.
            Err(_) => break,
        }
    }
}

/// Result of polling one pending entry.
enum Polled {
    NotReady,
    Reply(Frame),
}

/// Deliver every completed reply; returns `Err` only when the socket
/// write fails (the connection is then torn down by the caller).
fn flush_pending(shared: &Shared, stream: &TcpStream,
                 pending: &mut Vec<Pending>) -> io::Result<()> {
    let epoch_now = shared.epoch.load(Ordering::Acquire);
    let mut i = 0;
    while i < pending.len() {
        let polled = match &pending[i] {
            Pending::Score { id, rx, .. } => match rx.try_recv() {
                Ok(resp) => Polled::Reply(score_frame(*id, resp)),
                Err(TryRecvError::Empty) => Polled::NotReady,
                Err(TryRecvError::Disconnected) => Polled::Reply(
                    Frame::error(*id, epoch_now, ErrorCode::Internal,
                                 "reply channel closed", vec![])),
            },
            Pending::Update { id, rx, .. } => match rx.try_recv() {
                Ok(resp) => {
                    Polled::Reply(update_frame(*id, epoch_now, resp))
                }
                Err(TryRecvError::Empty) => Polled::NotReady,
                Err(TryRecvError::Disconnected) => Polled::Reply(
                    Frame::error(*id, epoch_now, ErrorCode::Internal,
                                 "reply channel closed", vec![])),
            },
            Pending::Stats { id, rx, .. } => match rx.try_recv() {
                Ok(snap) => Polled::Reply(Frame::new(
                    FrameKind::StatsOk, *id, epoch_now,
                    snap.to_benchkit_value())),
                Err(TryRecvError::Empty) => Polled::NotReady,
                Err(TryRecvError::Disconnected) => Polled::Reply(
                    Frame::error(*id, epoch_now, ErrorCode::Internal,
                                 "reply channel closed", vec![])),
            },
        };
        match polled {
            Polled::NotReady => i += 1,
            Polled::Reply(f) => {
                let entry = pending.swap_remove(i);
                shared.inflight.fetch_sub(1, Ordering::AcqRel);
                shared.frame_lat.record(entry.submitted().elapsed());
                // `net.write` models the reply write failing (peer
                // reset, kernel buffer error): the connection is
                // torn down by the caller and the client must
                // reconnect — inflight accounting above already
                // released this entry.
                crate::fault::point("net.write")?;
                frame::write_frame(&mut &*stream, &f, entry.mode())?;
            }
        }
    }
    Ok(())
}

fn score_frame(id: u64, resp: ScoreResponse) -> Frame {
    match resp {
        ScoreResponse::Ok(ok) => Frame::new(
            FrameKind::ScoreOk, id, ok.epoch,
            json::obj(vec![
                ("node", json::num(ok.node as f64)),
                ("logits", json::arr(ok.logits.iter()
                    .map(|v| json::num(*v as f64)).collect())),
                ("latency_us",
                 json::num(ok.latency.as_micros() as f64)),
            ])),
        ScoreResponse::Err(e) => {
            let (code, msg, extra) = match &e.reject {
                ScoreReject::NodeOutOfRange { node, n } => (
                    ErrorCode::NodeOutOfRange,
                    format!("node {node} out of range (n={n})"),
                    vec![("node", json::num(*node as f64)),
                         ("n", json::num(*n as f64))],
                ),
                ScoreReject::FeatureLen { got, want } => (
                    ErrorCode::FeatureLen,
                    format!("feature row has {got} values, want {want}"),
                    vec![("got", json::num(*got as f64)),
                         ("want", json::num(*want as f64))],
                ),
                ScoreReject::ExecFailed { message } => (
                    ErrorCode::ExecFailed,
                    message.clone(),
                    vec![],
                ),
                ScoreReject::EpochMismatch { pinned, current } => (
                    ErrorCode::EpochMismatch,
                    format!("pinned epoch {pinned}, serving {current}"),
                    vec![("pinned", json::num(*pinned as f64)),
                         ("current", json::num(*current as f64))],
                ),
            };
            Frame::error(id, e.epoch, code, &msg, extra)
        }
    }
}

fn update_frame(id: u64, epoch: u64, resp: UpdateResponse) -> Frame {
    Frame::new(
        FrameKind::UpdateOk, id, epoch,
        json::obj(vec![
            ("seq", json::num(resp.seq as f64)),
            ("outcome", json::str_(format!("{:?}", resp.outcome))),
            ("rebuild", json::str_(format!("{:?}", resp.rebuild))),
            ("cost_core", json::num(resp.cost_core as f64)),
            ("latency_us", json::num(resp.latency.as_micros() as f64)),
        ]))
}

/// Answer one request frame. Returns `false` when the connection
/// must close (protocol violation or dead transport).
fn dispatch(shared: &Shared, stream: &TcpStream,
            pending: &mut Vec<Pending>, f: Frame, mode: Mode,
            flight_dumped: &mut bool) -> bool {
    let epoch_now = shared.epoch.load(Ordering::Acquire);
    let reply = |frm: &Frame| -> bool {
        frame::write_frame(&mut &*stream, frm, mode).is_ok()
    };
    match f.kind {
        FrameKind::Ping => reply(&Frame::new(
            FrameKind::Pong, f.request_id, epoch_now, Value::Null)),
        FrameKind::ScoreReq => {
            if shared.draining.load(Ordering::Acquire) {
                shared.drained.inc();
                return reply(&Frame::error(
                    f.request_id, epoch_now, ErrorCode::Draining,
                    "server is draining", vec![]));
            }
            if let Some(why) = admission(shared, pending) {
                return shed(shared, stream, f.request_id, epoch_now,
                            mode, why);
            }
            let (node, features, pin) = match parse_score(&f) {
                Ok(v) => v,
                Err(msg) => {
                    return payload_error(shared, stream, &f, epoch_now,
                                         mode, &msg, flight_dumped);
                }
            };
            let (tx, rx) = srv::oneshot();
            let req = ScoreRequest {
                node,
                features,
                reply: tx,
                submitted: Instant::now(),
                pin_epoch: pin,
            };
            enqueue(shared, stream, pending, ServerMsg::Score(req),
                    Pending::Score {
                        id: f.request_id,
                        mode,
                        submitted: Instant::now(),
                        rx,
                    },
                    f.request_id, epoch_now, mode)
        }
        FrameKind::UpdateReq => {
            if shared.draining.load(Ordering::Acquire) {
                shared.drained.inc();
                return reply(&Frame::error(
                    f.request_id, epoch_now, ErrorCode::Draining,
                    "server is draining", vec![]));
            }
            if let Some(why) = admission(shared, pending) {
                return shed(shared, stream, f.request_id, epoch_now,
                            mode, why);
            }
            let delta = match parse_update(&f) {
                Ok(d) => d,
                Err(msg) => {
                    return payload_error(shared, stream, &f, epoch_now,
                                         mode, &msg, flight_dumped);
                }
            };
            let (tx, rx) = srv::update_oneshot();
            let req = UpdateRequest {
                delta,
                reply: Some(tx),
                submitted: Instant::now(),
            };
            enqueue(shared, stream, pending, ServerMsg::Update(req),
                    Pending::Update {
                        id: f.request_id,
                        mode,
                        submitted: Instant::now(),
                        rx,
                    },
                    f.request_id, epoch_now, mode)
        }
        FrameKind::StatsReq => {
            // Stats bypass the backlog gate (cheap, answered from the
            // receive loop) but still respect the pipeline cap.
            if pending.len() >= shared.cfg.max_inflight {
                return shed(shared, stream, f.request_id, epoch_now,
                            mode, "connection pipeline full");
            }
            let (tx, rx) = srv::stats_oneshot();
            enqueue(shared, stream, pending,
                    ServerMsg::Stats(StatsRequest { reply: tx }),
                    Pending::Stats {
                        id: f.request_id,
                        mode,
                        submitted: Instant::now(),
                        rx,
                    },
                    f.request_id, epoch_now, mode)
        }
        // Response kinds flowing client → server are protocol abuse.
        FrameKind::ScoreOk | FrameKind::UpdateOk | FrameKind::StatsOk
        | FrameKind::Error | FrameKind::Pong => {
            let e = WireError::Bad(format!(
                "unexpected {} frame from client", f.kind.name()));
            protocol_error(shared, stream, mode, &e, flight_dumped);
            false
        }
    }
}

/// Gates 2 and 3 (gate 1 lives at accept time). `None` = admitted.
fn admission(shared: &Shared,
             pending: &[Pending]) -> Option<&'static str> {
    if pending.len() >= shared.cfg.max_inflight {
        Some("connection pipeline full")
    } else if shared.inflight.load(Ordering::Acquire)
        >= shared.cfg.shed_after
    {
        Some("server backlog full")
    } else {
        None
    }
}

/// try_send into the batcher queue; a full queue sheds, a closed
/// queue reports `Internal` and closes the connection.
fn enqueue(shared: &Shared, stream: &TcpStream,
           pending: &mut Vec<Pending>, msg: ServerMsg, entry: Pending,
           id: u64, epoch: u64, mode: Mode) -> bool {
    match shared.queue.try_send(msg) {
        Ok(()) => {
            shared.inflight.fetch_add(1, Ordering::AcqRel);
            pending.push(entry);
            true
        }
        Err(TrySendError::Full(_)) => {
            shed(shared, stream, id, epoch, mode, "batcher queue full")
        }
        Err(TrySendError::Disconnected(_)) => {
            let f = Frame::error(id, epoch, ErrorCode::Internal,
                                 "batcher is gone", vec![]);
            let _ = frame::write_frame(&mut &*stream, &f, mode);
            false
        }
    }
}

fn shed(shared: &Shared, stream: &TcpStream, id: u64, epoch: u64,
        mode: Mode, why: &str) -> bool {
    shared.shed.inc();
    crate::obs_event!("net.shed", 1);
    let f = Frame::error(id, epoch, ErrorCode::RetryAfter, why,
                         vec![("retry_after_ms",
                               json::num(RETRY_AFTER_MS))]);
    frame::write_frame(&mut &*stream, &f, mode).is_ok()
}

fn parse_score(f: &Frame)
               -> Result<(u32, Vec<f32>, Option<u64>), String> {
    let node = f
        .payload
        .get("node")
        .and_then(|v| v.as_f64())
        .filter(|n| *n >= 0.0 && n.fract() == 0.0
                && *n <= u32::MAX as f64)
        .ok_or("score_req needs a \"node\" (non-negative integer)")?
        as u32;
    let features = match f.payload.get("features") {
        None | Some(Value::Null) => Vec::new(),
        Some(v) => {
            let arr = v.as_arr()
                .ok_or("\"features\" must be an array of numbers")?;
            let mut out = Vec::with_capacity(arr.len());
            for x in arr {
                out.push(x.as_f64().ok_or(
                    "\"features\" must be an array of numbers")?
                    as f32);
            }
            out
        }
    };
    // Header epoch pins when non-zero; the text form can also spell
    // it as payload.pin_epoch.
    let pin = if f.epoch != 0 {
        Some(f.epoch)
    } else {
        match f.payload.get("pin_epoch").and_then(|v| v.as_f64()) {
            Some(e) if e >= 1.0 && e.fract() == 0.0 => Some(e as u64),
            Some(_) => return Err(
                "\"pin_epoch\" must be a positive integer".into()),
            None => None,
        }
    };
    Ok((node, features, pin))
}

fn parse_update(f: &Frame) -> Result<GraphDelta, String> {
    let op = f
        .payload
        .get("op")
        .and_then(|v| v.as_str())
        .ok_or("update_req needs an \"op\" string")?;
    let endpoint = |key: &str| -> Result<u32, String> {
        f.payload
            .get(key)
            .and_then(|v| v.as_f64())
            .filter(|n| *n >= 0.0 && n.fract() == 0.0
                    && *n <= u32::MAX as f64)
            .map(|n| n as u32)
            .ok_or(format!("update_req op {op:?} needs {key:?} \
                            (non-negative integer)"))
    };
    match op {
        "edge_insert" => Ok(GraphDelta::EdgeInsert {
            src: endpoint("src")?,
            dst: endpoint("dst")?,
        }),
        "edge_delete" => Ok(GraphDelta::EdgeDelete {
            src: endpoint("src")?,
            dst: endpoint("dst")?,
        }),
        "node_add" => Ok(GraphDelta::NodeAdd),
        other => Err(format!("unknown update op {other:?}")),
    }
}

/// A structurally valid frame with a nonsense payload: answered with
/// `BadFrame` and the connection closes (same policy as wire-level
/// violations, so clients get one consistent contract).
fn payload_error(shared: &Shared, stream: &TcpStream, f: &Frame,
                 epoch: u64, mode: Mode, msg: &str,
                 flight_dumped: &mut bool) -> bool {
    let e = WireError::Bad(msg.to_string());
    let frm = Frame::error(f.request_id, epoch, ErrorCode::BadFrame,
                           msg, vec![]);
    let _ = frame::write_frame(&mut &*stream, &frm, mode);
    note_protocol_error(shared, &e, flight_dumped);
    false
}

/// Wire-level violation: count it, flight-dump once per connection,
/// answer with a final error frame (best effort), close.
fn protocol_error(shared: &Shared, stream: &TcpStream, mode: Mode,
                  e: &WireError, flight_dumped: &mut bool) {
    let epoch = shared.epoch.load(Ordering::Acquire);
    let frm = match e {
        WireError::Oversized { len, max } => Some(Frame::error(
            0, epoch, ErrorCode::Oversized,
            &format!("payload {len} bytes exceeds cap {max}"),
            vec![("len", json::num(*len as f64)),
                 ("max", json::num(*max as f64))])),
        WireError::Bad(m) => Some(Frame::error(
            0, epoch, ErrorCode::BadFrame, m, vec![])),
        WireError::Stalled => Some(Frame::error(
            0, epoch, ErrorCode::BadFrame, "peer stalled mid-frame",
            vec![])),
        // Transport already gone: nothing to answer.
        WireError::Eof | WireError::Io(_) => None,
    };
    if let Some(frm) = frm {
        let _ = frame::write_frame(&mut &*stream, &frm, mode);
    }
    note_protocol_error(shared, e, flight_dumped);
}

fn note_protocol_error(shared: &Shared, e: &WireError,
                       flight_dumped: &mut bool) {
    shared.proto_errors.inc();
    crate::obs_warn!("[net] protocol error: {e}");
    if !*flight_dumped {
        *flight_dumped = true;
        let _ = flight::dump("net.protocol_error", &shared.registry);
    }
}
