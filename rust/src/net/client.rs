//! Minimal blocking client SDK for the wire protocol, used by
//! `examples/serve_client.rs` and the numbered conformance suite.
//!
//! The SDK is strictly sequential — one outstanding request per call
//! — and speaks the binary encoding. Raw access (`send`, `send_raw`,
//! `recv`) is exposed for tests that need to pipeline, stall, or
//! send malformed bytes on purpose.

use std::io::{self, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use crate::util::json::{self, Value};
use crate::util::rng::Rng;

use super::frame::{self, ErrorCode, Frame, FrameKind, Mode, WireError};

/// Backoff schedule for [`Client::score_with_retry`].
///
/// Retries apply only to *recoverable load rejections* —
/// [`ErrorCode::RetryAfter`] (admission shed) and
/// [`ErrorCode::Draining`] — where the server explicitly invites a
/// later attempt. Everything else (transport errors, protocol
/// violations, semantic rejections like `node_out_of_range`) is
/// returned to the caller immediately: retrying cannot change the
/// answer.
///
/// The delay before attempt `k` is
/// `max(server retry_after_ms hint, base * 2^k)` capped at `cap`,
/// then stretched by up to +25% of deterministic jitter so a herd of
/// shed clients does not re-arrive in lockstep. The hint is a floor,
/// never reduced by the jitter or the cap.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Total attempts including the first (1 = no retries).
    pub max_attempts: u32,
    /// First-retry backoff before the exponential doubling.
    pub base: Duration,
    /// Upper bound on the computed backoff (the server hint may
    /// still exceed it).
    pub cap: Duration,
    /// Seed for the deterministic jitter stream.
    pub jitter_seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 5,
            base: Duration::from_millis(25),
            cap: Duration::from_secs(1),
            jitter_seed: 0x7265_7472_79,
        }
    }
}

impl RetryPolicy {
    /// Whether a rejection with `code` is worth retrying.
    pub fn retryable(code: ErrorCode) -> bool {
        matches!(code, ErrorCode::RetryAfter | ErrorCode::Draining)
    }

    /// Delay before retry number `attempt` (0-based), honoring the
    /// server's `retry_after_ms` hint as a floor. Pure — the caller
    /// owns the jitter stream, so schedules are reproducible.
    pub fn delay(&self, attempt: u32, hint_ms: Option<u64>,
                 rng: &mut Rng) -> Duration {
        let shift = 1u32.checked_shl(attempt).unwrap_or(u32::MAX);
        let backoff = self.base.saturating_mul(shift).min(self.cap);
        let floor = Duration::from_millis(hint_ms.unwrap_or(0));
        let target = backoff.max(floor);
        target.mul_f64(1.0 + 0.25 * rng.f64())
    }
}

/// A successful scoring answer.
#[derive(Debug, Clone)]
pub struct Score {
    /// Plan epoch the answer was computed under.
    pub epoch: u64,
    pub logits: Vec<f32>,
    pub latency_us: u64,
}

/// A decoded server error frame.
#[derive(Debug, Clone)]
pub struct WireRejection {
    pub code: ErrorCode,
    pub message: String,
    /// Serving epoch at rejection time.
    pub epoch: u64,
    /// For [`ErrorCode::EpochMismatch`]: the epoch the request pinned.
    pub pinned: Option<u64>,
    /// For [`ErrorCode::EpochMismatch`]: the epoch being served.
    pub current: Option<u64>,
    /// For [`ErrorCode::RetryAfter`]: suggested back-off.
    pub retry_after_ms: Option<u64>,
}

impl WireRejection {
    /// Decode an `Error` frame; `None` if it is not one (or the
    /// payload lacks a valid code).
    pub fn from_frame(f: &Frame) -> Option<WireRejection> {
        let code = f.error_code()?;
        let num = |key: &str| {
            f.payload
                .get(key)
                .and_then(|v| v.as_f64())
                .filter(|n| *n >= 0.0)
                .map(|n| n as u64)
        };
        Some(WireRejection {
            code,
            message: f.message().unwrap_or("").to_string(),
            epoch: f.epoch,
            pinned: num("pinned"),
            current: num("current"),
            retry_after_ms: num("retry_after_ms"),
        })
    }
}

impl std::fmt::Display for WireRejection {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.code.name(), self.message)
    }
}

/// Request outcome: the server answered, either with the result or
/// with a well-formed rejection (connection still usable unless the
/// code is non-recoverable).
#[derive(Debug, Clone)]
pub enum Outcome<T> {
    Ok(T),
    Rejected(WireRejection),
}

impl<T> Outcome<T> {
    pub fn into_result(self) -> Result<T, WireRejection> {
        match self {
            Outcome::Ok(v) => Ok(v),
            Outcome::Rejected(r) => Err(r),
        }
    }

    pub fn rejection(&self) -> Option<&WireRejection> {
        match self {
            Outcome::Ok(_) => None,
            Outcome::Rejected(r) => Some(r),
        }
    }
}

/// Why a client call failed outright (no usable server answer).
#[derive(Debug)]
pub enum ClientError {
    Io(io::Error),
    Wire(WireError),
    /// The reply was well-framed but not what the request expects
    /// (wrong kind, wrong id, missing payload field).
    Protocol(String),
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Io(e) => write!(f, "io: {e}"),
            ClientError::Wire(e) => write!(f, "wire: {e}"),
            ClientError::Protocol(m) => write!(f, "protocol: {m}"),
        }
    }
}

impl std::error::Error for ClientError {}

impl From<io::Error> for ClientError {
    fn from(e: io::Error) -> Self {
        ClientError::Io(e)
    }
}

impl From<WireError> for ClientError {
    fn from(e: WireError) -> Self {
        ClientError::Wire(e)
    }
}

/// An acknowledged topology update.
#[derive(Debug, Clone)]
pub struct UpdateAck {
    pub seq: u64,
    pub outcome: String,
    pub rebuild: String,
    pub cost_core: u64,
    pub latency_us: u64,
    pub epoch: u64,
}

/// Blocking wire client.
pub struct Client {
    stream: TcpStream,
    next_id: u64,
    max_payload: u32,
    stall: Duration,
}

impl Client {
    pub fn connect(addr: impl ToSocketAddrs) -> io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let stall = Duration::from_secs(30);
        stream.set_read_timeout(Some(stall))?;
        stream.set_write_timeout(Some(Duration::from_secs(10)))?;
        Ok(Client {
            stream,
            next_id: 0,
            max_payload: frame::DEFAULT_MAX_PAYLOAD,
            stall,
        })
    }

    /// How long `recv` waits for a reply before giving up.
    pub fn set_read_timeout(&mut self, d: Duration) -> io::Result<()> {
        self.stall = d;
        self.stream.set_read_timeout(Some(d))
    }

    pub fn stream(&self) -> &TcpStream {
        &self.stream
    }

    fn fresh_id(&mut self) -> u64 {
        self.next_id += 1;
        self.next_id
    }

    // ---- raw layer (conformance suite) ----

    /// Write one binary frame.
    pub fn send(&mut self, f: &Frame) -> io::Result<()> {
        frame::write_frame(&mut self.stream, f, Mode::Binary)
    }

    /// Write arbitrary bytes — for tests that violate the protocol
    /// on purpose (bad magic, truncated headers, stalls).
    pub fn send_raw(&mut self, bytes: &[u8]) -> io::Result<()> {
        self.stream.write_all(bytes)?;
        self.stream.flush()
    }

    /// Read one frame (either encoding).
    pub fn recv(&mut self) -> Result<Frame, WireError> {
        frame::read_frame(&mut self.stream, self.max_payload,
                          self.stall)
            .map(|(f, _)| f)
    }

    /// Send a request and wait for its reply. The sequential SDK
    /// expects the very next frame to answer this request;
    /// connection-level error frames (id 0) are also accepted.
    fn roundtrip(&mut self, kind: FrameKind, epoch: u64,
                 payload: Value) -> Result<Frame, ClientError> {
        let id = self.fresh_id();
        self.send(&Frame::new(kind, id, epoch, payload))?;
        let reply = self.recv()?;
        if reply.request_id != id && reply.request_id != 0 {
            return Err(ClientError::Protocol(format!(
                "reply id {} does not match request id {id}",
                reply.request_id)));
        }
        Ok(reply)
    }

    fn expect<T>(&self, reply: &Frame, want: FrameKind,
                 parse: impl FnOnce(&Frame) -> Result<T, String>)
                 -> Result<Outcome<T>, ClientError> {
        if reply.kind == FrameKind::Error {
            let rej = WireRejection::from_frame(reply).ok_or_else(|| {
                ClientError::Protocol(
                    "error frame without a valid code".into())
            })?;
            return Ok(Outcome::Rejected(rej));
        }
        if reply.kind != want {
            return Err(ClientError::Protocol(format!(
                "expected {} or error, got {}", want.name(),
                reply.kind.name())));
        }
        parse(reply).map(Outcome::Ok).map_err(ClientError::Protocol)
    }

    // ---- high-level calls ----

    /// Score `node`, optionally replacing its feature row first
    /// (empty slice = keep current features).
    pub fn score(&mut self, node: u32, features: &[f32])
                 -> Result<Outcome<Score>, ClientError> {
        self.score_pinned(node, features, None)
    }

    /// Score with an optional epoch pin: `Some(e)` demands the
    /// answer be computed under plan epoch `e` exactly, else the
    /// server rejects with `epoch_mismatch`.
    pub fn score_pinned(&mut self, node: u32, features: &[f32],
                        pin: Option<u64>)
                        -> Result<Outcome<Score>, ClientError> {
        let mut pairs = vec![("node", json::num(node as f64))];
        if !features.is_empty() {
            pairs.push(("features", json::arr(
                features.iter().map(|v| json::num(*v as f64))
                    .collect())));
        }
        let reply = self.roundtrip(FrameKind::ScoreReq,
                                   pin.unwrap_or(0),
                                   json::obj(pairs))?;
        self.expect(&reply, FrameKind::ScoreOk, |f| {
            let logits = f
                .payload
                .req_arr("logits")
                .map_err(|e| e.to_string())?
                .iter()
                .map(|v| v.as_f64().map(|x| x as f32)
                    .ok_or("non-numeric logit".to_string()))
                .collect::<Result<Vec<f32>, _>>()?;
            let latency_us = f
                .payload
                .req_f64("latency_us")
                .map_err(|e| e.to_string())? as u64;
            Ok(Score { epoch: f.epoch, logits, latency_us })
        })
    }

    /// [`score`](Client::score) wrapped in the retry loop described
    /// on [`RetryPolicy`]: recoverable load rejections (`retry_after`
    /// / `draining`) are retried up to `policy.max_attempts` with
    /// capped jittered exponential backoff, honoring the server's
    /// `retry_after_ms` hint as a floor. The final outcome — success
    /// or the last rejection — is returned; transport errors and
    /// non-recoverable rejections surface immediately.
    pub fn score_with_retry(&mut self, node: u32, features: &[f32],
                            policy: &RetryPolicy)
                            -> Result<Outcome<Score>, ClientError> {
        let mut rng = Rng::seed_from_u64(
            policy.jitter_seed ^ (node as u64).rotate_left(17));
        let mut attempt = 0u32;
        loop {
            let out = self.score(node, features)?;
            let rej = match out.rejection() {
                None => return Ok(out),
                Some(r) => r,
            };
            if !RetryPolicy::retryable(rej.code)
                || attempt + 1 >= policy.max_attempts.max(1)
            {
                return Ok(out);
            }
            let d = policy.delay(attempt, rej.retry_after_ms,
                                 &mut rng);
            crate::obs_event!("client.retry", attempt as u64,
                              d.as_millis() as u64);
            std::thread::sleep(d);
            attempt += 1;
        }
    }

    fn update(&mut self, op: &str, src: Option<u32>, dst: Option<u32>)
              -> Result<Outcome<UpdateAck>, ClientError> {
        let mut pairs = vec![("op", json::str_(op))];
        if let Some(s) = src {
            pairs.push(("src", json::num(s as f64)));
        }
        if let Some(d) = dst {
            pairs.push(("dst", json::num(d as f64)));
        }
        let reply = self.roundtrip(FrameKind::UpdateReq, 0,
                                   json::obj(pairs))?;
        self.expect(&reply, FrameKind::UpdateOk, |f| {
            let g = |key: &str| {
                f.payload.req_f64(key).map(|n| n as u64)
                    .map_err(|e| e.to_string())
            };
            Ok(UpdateAck {
                seq: g("seq")?,
                outcome: f.payload.req_str("outcome")
                    .map_err(|e| e.to_string())?.to_string(),
                rebuild: f.payload.req_str("rebuild")
                    .map_err(|e| e.to_string())?.to_string(),
                cost_core: g("cost_core")?,
                latency_us: g("latency_us")?,
                epoch: f.epoch,
            })
        })
    }

    pub fn edge_insert(&mut self, src: u32, dst: u32)
                       -> Result<Outcome<UpdateAck>, ClientError> {
        self.update("edge_insert", Some(src), Some(dst))
    }

    pub fn edge_delete(&mut self, src: u32, dst: u32)
                       -> Result<Outcome<UpdateAck>, ClientError> {
        self.update("edge_delete", Some(src), Some(dst))
    }

    pub fn node_add(&mut self)
                    -> Result<Outcome<UpdateAck>, ClientError> {
        self.update("node_add", None, None)
    }

    /// Live stats snapshot as benchkit-v1 JSON.
    pub fn stats(&mut self) -> Result<Outcome<Value>, ClientError> {
        let reply = self.roundtrip(FrameKind::StatsReq, 0,
                                   Value::Null)?;
        self.expect(&reply, FrameKind::StatsOk,
                    |f| Ok(f.payload.clone()))
    }

    /// Liveness probe; returns the serving plan epoch.
    pub fn ping(&mut self) -> Result<u64, ClientError> {
        let reply = self.roundtrip(FrameKind::Ping, 0, Value::Null)?;
        if reply.kind != FrameKind::Pong {
            return Err(ClientError::Protocol(format!(
                "expected pong, got {}", reply.kind.name())));
        }
        Ok(reply.epoch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retry_policy_backoff_doubles_and_caps() {
        let p = RetryPolicy {
            max_attempts: 8,
            base: Duration::from_millis(25),
            cap: Duration::from_millis(200),
            jitter_seed: 1,
        };
        let mut rng = Rng::seed_from_u64(p.jitter_seed);
        let mut prev = Duration::ZERO;
        for attempt in 0..8 {
            let d = p.delay(attempt, None, &mut rng);
            let raw = Duration::from_millis(25 << attempt.min(3))
                .min(p.cap);
            assert!(d >= raw, "jitter never shrinks the backoff");
            assert!(d <= raw.mul_f64(1.25), "jitter bounded at +25%");
            assert!(d >= prev.mul_f64(0.8),
                    "schedule roughly monotone until the cap");
            prev = d;
        }
        // Past the doubling horizon the cap holds.
        let d = p.delay(31, None, &mut rng);
        assert!(d <= p.cap.mul_f64(1.25));
    }

    #[test]
    fn retry_policy_honors_server_hint_as_floor() {
        let p = RetryPolicy::default();
        let mut rng = Rng::seed_from_u64(7);
        // Hint above both the backoff and the cap still wins.
        let d = p.delay(0, Some(5_000), &mut rng);
        assert!(d >= Duration::from_millis(5_000));
        // Hint below the backoff is subsumed by it.
        let d = p.delay(4, Some(1), &mut rng);
        assert!(d >= Duration::from_millis(400));
    }

    #[test]
    fn retry_policy_schedule_is_deterministic_per_seed() {
        let p = RetryPolicy::default();
        let schedule = |seed: u64| -> Vec<Duration> {
            let mut rng = Rng::seed_from_u64(seed);
            (0..4).map(|a| p.delay(a, Some(50), &mut rng)).collect()
        };
        assert_eq!(schedule(42), schedule(42));
        assert_ne!(schedule(42), schedule(43),
                   "different seeds decorrelate the herd");
    }

    #[test]
    fn retry_policy_classifies_codes() {
        assert!(RetryPolicy::retryable(ErrorCode::RetryAfter));
        assert!(RetryPolicy::retryable(ErrorCode::Draining));
        for code in [
            ErrorCode::BadFrame,
            ErrorCode::Oversized,
            ErrorCode::EpochMismatch,
            ErrorCode::NodeOutOfRange,
            ErrorCode::FeatureLen,
            ErrorCode::ExecFailed,
            ErrorCode::Internal,
        ] {
            assert!(!RetryPolicy::retryable(code), "{}", code.name());
        }
    }
}
