//! Graceful shutdown for the TCP front end.
//!
//! The drain state machine has three stages:
//!
//! 1. **`begin_drain`** — stop accepting (the listener thread exits,
//!    so new connects are refused by the OS once the backlog empties)
//!    and flip the `draining` flag. Connection threads keep flushing
//!    replies for requests already in flight; any *new* score/update
//!    frame is answered with an explicit [`ErrorCode::Draining`]
//!    error frame (counted as `net.drained`) instead of being queued.
//! 2. **wait** — until the server-wide inflight gauge reaches zero
//!    and every connection thread has unwound, or the grace deadline
//!    passes.
//! 3. **halt** — flip `stopped` (connection loops exit at the next
//!    tick regardless of state) and join all threads.
//!
//! Order matters for the caller: drain the net front end *first*,
//! then shut down the [`crate::coordinator::InferenceServer`] — the
//! in-flight batches being flushed in stage 2 need a live batcher.
//!
//! [`ErrorCode::Draining`]: super::frame::ErrorCode::Draining

use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use super::listener::NetServer;

/// Final counter snapshot for a front end's lifetime.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct NetStats {
    /// Connections accepted (gate-1 refusals are counted in `shed`).
    pub accepted: u64,
    /// Requests (or connections) load-shed with `RetryAfter`.
    pub shed: u64,
    /// Requests answered with `Draining` during shutdown.
    pub drained: u64,
    /// Wire-contract violations (each also closed its connection).
    pub protocol_errors: u64,
}

impl NetServer {
    /// Point-in-time `net.*` counter values.
    pub fn stats(&self) -> NetStats {
        NetStats {
            accepted: self.shared.accepted.get(),
            shed: self.shared.shed.get(),
            drained: self.shared.drained.get(),
            protocol_errors: self.shared.proto_errors.get(),
        }
    }

    /// Stage 1: stop accepting and start answering new work with
    /// `Draining`. Idempotent; [`drain`](NetServer::drain) calls it
    /// implicitly, but tests (and operators wiring a signal handler)
    /// can trigger it early and keep the handle.
    pub fn begin_drain(&self) {
        self.shared.accepting.store(false, Ordering::Release);
        self.shared.draining.store(true, Ordering::Release);
        crate::obs_event!("net.drain_begin", 1);
    }

    /// Full graceful shutdown: stage 1, then wait up to `grace` for
    /// in-flight requests to flush and connections to unwind, then
    /// halt and join every thread. Returns the final counters.
    pub fn drain(mut self, grace: Duration) -> NetStats {
        self.begin_drain();
        let deadline = Instant::now() + grace;
        while Instant::now() < deadline {
            let inflight =
                self.shared.inflight.load(Ordering::Acquire);
            let conns =
                self.shared.active_conns.load(Ordering::Acquire);
            if inflight == 0 && conns == 0 {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        self.halt();
        let stats = self.stats();
        crate::obs_event!("net.drained_total", stats.drained);
        stats
    }

    /// Impatient shutdown with a short grace window — the drop-in
    /// counterpart to `InferenceServer::shutdown`.
    pub fn shutdown(self) -> NetStats {
        self.drain(Duration::from_secs(5))
    }

    /// Stage 3: force every loop to exit and join all threads.
    /// Idempotent (handles are taken).
    fn halt(&mut self) {
        self.shared.accepting.store(false, Ordering::Release);
        self.shared.stopped.store(true, Ordering::Release);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let handles: Vec<_> = {
            let mut g = self.conns.lock().unwrap();
            g.drain(..).collect()
        };
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.halt();
    }
}
