//! Wire-level serving: a std-only TCP front end over the channel API
//! in [`crate::coordinator::server`].
//!
//! Layering (see DESIGN.md §12 for the full contract):
//!
//! - [`frame`] — length-prefixed binary framing with a JSON text
//!   fallback; the versioned header carries a request id and the
//!   **plan epoch** so clients can pin reads across hot plan swaps.
//! - [`listener`] — bounded accept loop feeding the batcher queue;
//!   three-gate admission control that load-sheds with `RetryAfter`
//!   frames instead of buffering unboundedly; per-connection
//!   read/write timeouts.
//! - [`drain`] — graceful shutdown: stop accepting, flush in-flight
//!   batches, answer stragglers with `Draining`.
//! - [`client`] — minimal blocking SDK shared by
//!   `examples/serve_client.rs` and the conformance suite.
//!
//! The front end deliberately takes the *raw* batcher queue and
//! epoch cell rather than an `InferenceServer` handle: production
//! wiring passes `server.client()` / `server.epoch_cell()`, while
//! the conformance suite substitutes a test-owned channel and drives
//! the batcher side by script — every shed/drain/epoch behavior is
//! then deterministic.

pub mod client;
pub mod drain;
pub mod frame;
pub mod listener;

pub use client::{Client, ClientError, Outcome, RetryPolicy, Score,
                 UpdateAck, WireRejection};
pub use drain::NetStats;
pub use frame::{ErrorCode, Frame, FrameKind, Mode, WireError};
pub use listener::{NetConfig, NetServer};
