//! Wire framing for the TCP serving front end.
//!
//! Two encodings share one logical frame model:
//!
//! **Binary** (the default, used by [`crate::net::client::Client`]):
//! a fixed 24-byte little-endian header followed by an optional UTF-8
//! JSON payload.
//!
//! ```text
//!   offset  size  field
//!   0       2     magic        0x4841 ("HA", LE on the wire: 41 48)
//!   2       1     version      1
//!   3       1     kind         FrameKind discriminant
//!   4       8     request_id   client-chosen correlation id
//!   12      8     epoch        plan epoch (see below)
//!   20      4     payload_len  bytes of JSON following the header
//! ```
//!
//! **Text fallback**: if the *first byte* a peer sends on a connection
//! (or of any subsequent frame) is `{`, the frame is one JSON object
//! terminated by `\n`:
//! `{"type":"score_req","id":7,"epoch":0,"payload":{...}}`.
//! A connection may mix encodings frame-by-frame; the server answers
//! each request in the encoding it arrived in, so `nc` sessions get
//! readable replies while binary SDK traffic stays compact.
//!
//! **Epoch semantics.** In *responses* the header epoch is the plan
//! epoch the answer was computed under (strictly monotone across hot
//! swaps, starting at 1 for the spawn-time plan). In *requests* a
//! non-zero epoch pins the read: the server answers only while it is
//! serving exactly that epoch and otherwise returns an
//! [`ErrorCode::EpochMismatch`] error frame carrying both `pinned`
//! and `current`. Epoch 0 in a request means "unpinned".
//!
//! Request/response ids and epochs ride the binary header exactly
//! (u64); the JSON text form carries them as numbers and is therefore
//! exact only below 2^53 — far beyond any realistic epoch or id.

use std::io::{self, Read, Write};
use std::time::{Duration, Instant};

use crate::util::json::{self, Value};

/// `0x4841` = ASCII "HA" (HAG wire).
pub const MAGIC: u16 = 0x4841;
/// Current protocol version. Bump on any incompatible header change.
pub const VERSION: u8 = 1;
/// Fixed binary header size in bytes.
pub const HEADER_LEN: usize = 24;
/// Default payload cap (1 MiB) — a dense feature row at f_in=1024 is
/// ~12 KiB of JSON, so this leaves two orders of magnitude headroom
/// while still bounding a hostile `payload_len`.
pub const DEFAULT_MAX_PAYLOAD: u32 = 1 << 20;

/// Frame discriminant. Requests are odd-kinded by convention except
/// `Error`, which only ever flows server → client.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    ScoreReq = 1,
    ScoreOk = 2,
    Error = 3,
    UpdateReq = 4,
    UpdateOk = 5,
    StatsReq = 6,
    StatsOk = 7,
    Ping = 8,
    Pong = 9,
}

impl FrameKind {
    pub fn from_u8(b: u8) -> Option<FrameKind> {
        Some(match b {
            1 => FrameKind::ScoreReq,
            2 => FrameKind::ScoreOk,
            3 => FrameKind::Error,
            4 => FrameKind::UpdateReq,
            5 => FrameKind::UpdateOk,
            6 => FrameKind::StatsReq,
            7 => FrameKind::StatsOk,
            8 => FrameKind::Ping,
            9 => FrameKind::Pong,
            _ => return None,
        })
    }

    pub fn as_u8(self) -> u8 {
        self as u8
    }

    /// Stable name used by the JSON text encoding's `"type"` field.
    pub fn name(self) -> &'static str {
        match self {
            FrameKind::ScoreReq => "score_req",
            FrameKind::ScoreOk => "score_ok",
            FrameKind::Error => "error",
            FrameKind::UpdateReq => "update_req",
            FrameKind::UpdateOk => "update_ok",
            FrameKind::StatsReq => "stats_req",
            FrameKind::StatsOk => "stats_ok",
            FrameKind::Ping => "ping",
            FrameKind::Pong => "pong",
        }
    }

    pub fn from_name(s: &str) -> Option<FrameKind> {
        Some(match s {
            "score_req" => FrameKind::ScoreReq,
            "score_ok" => FrameKind::ScoreOk,
            "error" => FrameKind::Error,
            "update_req" => FrameKind::UpdateReq,
            "update_ok" => FrameKind::UpdateOk,
            "stats_req" => FrameKind::StatsReq,
            "stats_ok" => FrameKind::StatsOk,
            "ping" => FrameKind::Ping,
            "pong" => FrameKind::Pong,
            _ => return None,
        })
    }
}

/// Error-frame code, carried in the payload as `"code"` (number) and
/// `"error"` (stable name). Codes 1–2 are protocol violations (the
/// server closes the connection after answering), 3–4 are admission
/// outcomes (retry-able), 5–9 are per-request rejections (the
/// connection stays healthy).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    BadFrame = 1,
    Oversized = 2,
    RetryAfter = 3,
    Draining = 4,
    EpochMismatch = 5,
    NodeOutOfRange = 6,
    FeatureLen = 7,
    ExecFailed = 8,
    Internal = 9,
}

impl ErrorCode {
    pub fn from_u16(v: u16) -> Option<ErrorCode> {
        Some(match v {
            1 => ErrorCode::BadFrame,
            2 => ErrorCode::Oversized,
            3 => ErrorCode::RetryAfter,
            4 => ErrorCode::Draining,
            5 => ErrorCode::EpochMismatch,
            6 => ErrorCode::NodeOutOfRange,
            7 => ErrorCode::FeatureLen,
            8 => ErrorCode::ExecFailed,
            9 => ErrorCode::Internal,
            _ => return None,
        })
    }

    pub fn as_u16(self) -> u16 {
        self as u16
    }

    pub fn name(self) -> &'static str {
        match self {
            ErrorCode::BadFrame => "bad_frame",
            ErrorCode::Oversized => "oversized",
            ErrorCode::RetryAfter => "retry_after",
            ErrorCode::Draining => "draining",
            ErrorCode::EpochMismatch => "epoch_mismatch",
            ErrorCode::NodeOutOfRange => "node_out_of_range",
            ErrorCode::FeatureLen => "feature_len",
            ErrorCode::ExecFailed => "exec_failed",
            ErrorCode::Internal => "internal",
        }
    }

    /// Whether the server keeps the connection open after sending an
    /// error frame with this code.
    pub fn recoverable(self) -> bool {
        !matches!(self, ErrorCode::BadFrame | ErrorCode::Oversized)
    }
}

/// Which encoding a frame arrived in / should leave in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Binary,
    Text,
}

/// One logical frame: header fields + decoded JSON payload
/// (`Value::Null` ⇔ empty payload on the wire).
#[derive(Debug, Clone)]
pub struct Frame {
    pub kind: FrameKind,
    pub request_id: u64,
    pub epoch: u64,
    pub payload: Value,
}

impl Frame {
    pub fn new(kind: FrameKind, request_id: u64, epoch: u64,
               payload: Value) -> Frame {
        Frame { kind, request_id, epoch, payload }
    }

    /// Build an error frame: `{"code":n,"error":name,"message":...}`
    /// plus any extra key/value pairs (e.g. `pinned`/`current` for
    /// epoch mismatches, `retry_after_ms` for sheds).
    pub fn error(request_id: u64, epoch: u64, code: ErrorCode,
                 message: &str, extra: Vec<(&str, Value)>) -> Frame {
        let mut pairs = vec![
            ("code", json::num(code.as_u16() as f64)),
            ("error", json::str_(code.name())),
            ("message", json::str_(message)),
        ];
        pairs.extend(extra);
        Frame::new(FrameKind::Error, request_id, epoch, json::obj(pairs))
    }

    /// For `Error` frames: the decoded [`ErrorCode`], if well-formed.
    pub fn error_code(&self) -> Option<ErrorCode> {
        if self.kind != FrameKind::Error {
            return None;
        }
        let code = self.payload.get("code")?.as_f64()?;
        if !(0.0..=u16::MAX as f64).contains(&code) {
            return None;
        }
        ErrorCode::from_u16(code as u16)
    }

    pub fn message(&self) -> Option<&str> {
        self.payload.get("message").and_then(|v| v.as_str())
    }
}

/// Why a frame could not be read.
#[derive(Debug)]
pub enum WireError {
    /// Protocol violation: bad magic/version/kind, junk payload,
    /// connection closed mid-frame. The connection is unusable.
    Bad(String),
    /// Declared payload length exceeds the cap; nothing past the
    /// header was read.
    Oversized { len: u32, max: u32 },
    /// Peer stopped sending mid-frame for longer than the stall
    /// budget (distinct from *idle* between frames, which the caller
    /// handles before the first byte).
    Stalled,
    /// Clean EOF before any byte of a frame.
    Eof,
    /// Underlying transport error (including read-timeout on the
    /// first byte when the caller uses blocking reads).
    Io(io::Error),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Bad(m) => write!(f, "bad frame: {m}"),
            WireError::Oversized { len, max } => {
                write!(f, "oversized payload: {len} bytes (max {max})")
            }
            WireError::Stalled => write!(f, "peer stalled mid-frame"),
            WireError::Eof => write!(f, "connection closed"),
            WireError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for WireError {}

fn timeoutish(e: &io::Error) -> bool {
    matches!(e.kind(),
             io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

/// `read_exact` with a stall deadline: short reads caused by a socket
/// read-timeout retry until `deadline`, then report [`WireError::Stalled`].
/// EOF mid-buffer is a protocol violation, not a clean close.
fn read_exact_deadline(r: &mut impl Read, buf: &mut [u8],
                       deadline: Instant) -> Result<(), WireError> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                return Err(WireError::Bad(
                    "connection closed mid-frame".into()));
            }
            Ok(n) => filled += n,
            Err(e) if timeoutish(&e) => {
                if Instant::now() >= deadline {
                    return Err(WireError::Stalled);
                }
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    Ok(())
}

/// Read one frame, consuming the first byte from `r` (blocking or
/// timing out per the stream's own read-timeout). Convenience wrapper
/// used by the client SDK; servers that need to distinguish idle from
/// mid-frame stalls read the first byte themselves and call
/// [`read_frame_after`].
pub fn read_frame(r: &mut impl Read, max_payload: u32,
                  stall: Duration) -> Result<(Frame, Mode), WireError> {
    let mut b = [0u8; 1];
    loop {
        match r.read(&mut b) {
            Ok(0) => return Err(WireError::Eof),
            Ok(_) => return read_frame_after(b[0], r, max_payload, stall),
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(WireError::Io(e)),
        }
    }
}

/// Read the remainder of a frame whose first byte is already in hand.
/// `{` selects the JSON text encoding; anything else must be the low
/// byte of the binary magic.
pub fn read_frame_after(first: u8, r: &mut impl Read, max_payload: u32,
                        stall: Duration)
                        -> Result<(Frame, Mode), WireError> {
    let deadline = Instant::now() + stall;
    if first == b'{' {
        return read_text_frame(r, max_payload, deadline)
            .map(|f| (f, Mode::Text));
    }
    if first != (MAGIC & 0xff) as u8 {
        return Err(WireError::Bad(format!(
            "bad magic byte 0x{first:02x}")));
    }
    let mut rest = [0u8; HEADER_LEN - 1];
    read_exact_deadline(r, &mut rest, deadline)?;
    let mut hdr = [0u8; HEADER_LEN];
    hdr[0] = first;
    hdr[1..].copy_from_slice(&rest);

    let magic = u16::from_le_bytes([hdr[0], hdr[1]]);
    if magic != MAGIC {
        return Err(WireError::Bad(format!("bad magic 0x{magic:04x}")));
    }
    let version = hdr[2];
    if version != VERSION {
        return Err(WireError::Bad(format!(
            "unsupported version {version} (want {VERSION})")));
    }
    let kind = FrameKind::from_u8(hdr[3]).ok_or_else(|| {
        WireError::Bad(format!("unknown frame kind {}", hdr[3]))
    })?;
    let request_id = u64::from_le_bytes(hdr[4..12].try_into().unwrap());
    let epoch = u64::from_le_bytes(hdr[12..20].try_into().unwrap());
    let payload_len = u32::from_le_bytes(hdr[20..24].try_into().unwrap());
    if payload_len > max_payload {
        return Err(WireError::Oversized { len: payload_len,
                                          max: max_payload });
    }
    let payload = if payload_len == 0 {
        Value::Null
    } else {
        let mut buf = vec![0u8; payload_len as usize];
        read_exact_deadline(r, &mut buf, deadline)?;
        let text = std::str::from_utf8(&buf).map_err(|_| {
            WireError::Bad("payload is not UTF-8".into())
        })?;
        json::parse(text).map_err(|e| {
            WireError::Bad(format!("payload is not JSON: {e}"))
        })?
    };
    Ok((Frame { kind, request_id, epoch, payload }, Mode::Binary))
}

/// Text fallback: the `{` is already consumed; read to `\n` (capped),
/// parse, lift `type`/`id`/`epoch`/`payload`.
fn read_text_frame(r: &mut impl Read, max_payload: u32,
                   deadline: Instant) -> Result<Frame, WireError> {
    let mut line = vec![b'{'];
    let mut b = [0u8; 1];
    loop {
        match r.read(&mut b) {
            Ok(0) => {
                return Err(WireError::Bad(
                    "connection closed mid-line".into()));
            }
            Ok(_) => {
                if b[0] == b'\n' {
                    break;
                }
                line.push(b[0]);
                if line.len() > max_payload as usize {
                    return Err(WireError::Oversized {
                        len: line.len() as u32,
                        max: max_payload,
                    });
                }
            }
            Err(e) if timeoutish(&e) => {
                if Instant::now() >= deadline {
                    return Err(WireError::Stalled);
                }
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    let text = std::str::from_utf8(&line)
        .map_err(|_| WireError::Bad("line is not UTF-8".into()))?;
    let v = json::parse(text.trim_end_matches('\r'))
        .map_err(|e| WireError::Bad(format!("bad JSON line: {e}")))?;
    let kind_name = v
        .get("type")
        .and_then(|t| t.as_str())
        .ok_or_else(|| WireError::Bad("missing \"type\"".into()))?;
    let kind = FrameKind::from_name(kind_name).ok_or_else(|| {
        WireError::Bad(format!("unknown type {kind_name:?}"))
    })?;
    let num_field = |key: &str| -> Result<u64, WireError> {
        match v.get(key) {
            None | Some(Value::Null) => Ok(0),
            Some(x) => x
                .as_f64()
                .filter(|n| *n >= 0.0 && n.fract() == 0.0)
                .map(|n| n as u64)
                .ok_or_else(|| {
                    WireError::Bad(format!("bad {key:?} field"))
                }),
        }
    };
    let request_id = num_field("id")?;
    let epoch = num_field("epoch")?;
    let payload = v.get("payload").cloned().unwrap_or(Value::Null);
    Ok(Frame { kind, request_id, epoch, payload })
}

/// Binary encoding of a frame (header + JSON payload bytes).
pub fn encode_binary(f: &Frame) -> Vec<u8> {
    let body = match &f.payload {
        Value::Null => String::new(),
        v => v.to_string(),
    };
    let mut out = Vec::with_capacity(HEADER_LEN + body.len());
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(VERSION);
    out.push(f.kind.as_u8());
    out.extend_from_slice(&f.request_id.to_le_bytes());
    out.extend_from_slice(&f.epoch.to_le_bytes());
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    out.extend_from_slice(body.as_bytes());
    out
}

/// Text encoding: one JSON object + `\n`.
pub fn encode_text(f: &Frame) -> String {
    let mut pairs = vec![
        ("type", json::str_(f.kind.name())),
        ("id", json::num(f.request_id as f64)),
        ("epoch", json::num(f.epoch as f64)),
    ];
    if f.payload != Value::Null {
        pairs.push(("payload", f.payload.clone()));
    }
    let mut s = json::obj(pairs).to_string();
    s.push('\n');
    s
}

/// Serialize in the given mode and write it out in one call.
pub fn write_frame(w: &mut impl Write, f: &Frame,
                   mode: Mode) -> io::Result<()> {
    match mode {
        Mode::Binary => w.write_all(&encode_binary(f))?,
        Mode::Text => w.write_all(encode_text(f).as_bytes())?,
    }
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    const STALL: Duration = Duration::from_secs(2);

    fn roundtrip(f: &Frame) -> Frame {
        let bytes = encode_binary(f);
        let mut r = &bytes[..];
        let (out, mode) =
            read_frame(&mut r, DEFAULT_MAX_PAYLOAD, STALL).unwrap();
        assert_eq!(mode, Mode::Binary);
        assert!(r.is_empty(), "trailing bytes after decode");
        out
    }

    #[test]
    fn binary_roundtrip_all_kinds() {
        for kind in [
            FrameKind::ScoreReq, FrameKind::ScoreOk, FrameKind::Error,
            FrameKind::UpdateReq, FrameKind::UpdateOk,
            FrameKind::StatsReq, FrameKind::StatsOk, FrameKind::Ping,
            FrameKind::Pong,
        ] {
            let f = Frame::new(
                kind,
                0xDEAD_BEEF_0BAD_CAFE,
                42,
                json::obj(vec![("node", json::num(3.0))]),
            );
            let out = roundtrip(&f);
            assert_eq!(out.kind, kind);
            assert_eq!(out.request_id, 0xDEAD_BEEF_0BAD_CAFE);
            assert_eq!(out.epoch, 42);
            assert_eq!(out.payload, f.payload);
            assert_eq!(FrameKind::from_u8(kind.as_u8()), Some(kind));
            assert_eq!(FrameKind::from_name(kind.name()), Some(kind));
        }
    }

    #[test]
    fn empty_payload_is_null() {
        let f = Frame::new(FrameKind::Ping, 1, 0, Value::Null);
        let bytes = encode_binary(&f);
        assert_eq!(bytes.len(), HEADER_LEN);
        assert_eq!(roundtrip(&f).payload, Value::Null);
    }

    #[test]
    fn text_roundtrip() {
        let f = Frame::new(
            FrameKind::ScoreReq,
            7,
            3,
            json::obj(vec![
                ("node", json::num(5.0)),
                ("features", json::arr(vec![json::num(0.5)])),
            ]),
        );
        let text = encode_text(&f);
        assert!(text.ends_with('\n'));
        let mut r = text.as_bytes();
        let (out, mode) =
            read_frame(&mut r, DEFAULT_MAX_PAYLOAD, STALL).unwrap();
        assert_eq!(mode, Mode::Text);
        assert_eq!(out.kind, FrameKind::ScoreReq);
        assert_eq!(out.request_id, 7);
        assert_eq!(out.epoch, 3);
        assert_eq!(out.payload, f.payload);
    }

    #[test]
    fn text_defaults_id_and_epoch_to_zero() {
        let mut r = "{\"type\":\"ping\"}\n".as_bytes();
        let (out, _) =
            read_frame(&mut r, DEFAULT_MAX_PAYLOAD, STALL).unwrap();
        assert_eq!(out.kind, FrameKind::Ping);
        assert_eq!(out.request_id, 0);
        assert_eq!(out.epoch, 0);
        assert_eq!(out.payload, Value::Null);
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = encode_binary(
            &Frame::new(FrameKind::Ping, 1, 0, Value::Null));
        bytes[1] = 0x00;
        let err = read_frame(&mut &bytes[..], DEFAULT_MAX_PAYLOAD, STALL)
            .unwrap_err();
        assert!(matches!(err, WireError::Bad(_)), "{err:?}");
        // First byte wrong: caught before the header is read.
        let err = read_frame_after(0x99, &mut &bytes[1..],
                                   DEFAULT_MAX_PAYLOAD, STALL)
            .unwrap_err();
        assert!(matches!(err, WireError::Bad(_)), "{err:?}");
    }

    #[test]
    fn bad_version_and_kind_rejected() {
        let mut bytes = encode_binary(
            &Frame::new(FrameKind::Ping, 1, 0, Value::Null));
        bytes[2] = 9;
        assert!(matches!(
            read_frame(&mut &bytes[..], DEFAULT_MAX_PAYLOAD, STALL),
            Err(WireError::Bad(_))
        ));
        bytes[2] = VERSION;
        bytes[3] = 200;
        assert!(matches!(
            read_frame(&mut &bytes[..], DEFAULT_MAX_PAYLOAD, STALL),
            Err(WireError::Bad(_))
        ));
    }

    #[test]
    fn oversized_payload_rejected_without_reading_it() {
        let f = Frame::new(FrameKind::ScoreReq, 1, 0,
                           json::obj(vec![("node", json::num(0.0))]));
        let bytes = encode_binary(&f);
        // Cap below the actual payload size: header alone triggers it.
        let err = read_frame(&mut &bytes[..], 4, STALL).unwrap_err();
        match err {
            WireError::Oversized { len, max } => {
                assert!(len > 4);
                assert_eq!(max, 4);
            }
            other => panic!("expected Oversized, got {other:?}"),
        }
    }

    #[test]
    fn truncated_frame_is_bad_not_eof() {
        let bytes = encode_binary(
            &Frame::new(FrameKind::Ping, 1, 0, Value::Null));
        let err = read_frame(&mut &bytes[..HEADER_LEN - 3],
                             DEFAULT_MAX_PAYLOAD, STALL)
            .unwrap_err();
        assert!(matches!(err, WireError::Bad(_)), "{err:?}");
        // But zero bytes is a clean EOF.
        assert!(matches!(
            read_frame(&mut &[][..], DEFAULT_MAX_PAYLOAD, STALL),
            Err(WireError::Eof)
        ));
    }

    #[test]
    fn junk_payload_rejected() {
        let f = Frame::new(FrameKind::Ping, 1, 0, Value::Null);
        let mut bytes = encode_binary(&f);
        bytes[20..24].copy_from_slice(&3u32.to_le_bytes());
        bytes.extend_from_slice(b"}{!");
        assert!(matches!(
            read_frame(&mut &bytes[..], DEFAULT_MAX_PAYLOAD, STALL),
            Err(WireError::Bad(_))
        ));
        // Text side: a line that is not JSON.
        let mut r = "{nope\n".as_bytes();
        assert!(matches!(
            read_frame(&mut r, DEFAULT_MAX_PAYLOAD, STALL),
            Err(WireError::Bad(_))
        ));
        // Text side: valid JSON, unknown type.
        let mut r = "{\"type\":\"bogus\"}\n".as_bytes();
        assert!(matches!(
            read_frame(&mut r, DEFAULT_MAX_PAYLOAD, STALL),
            Err(WireError::Bad(_))
        ));
    }

    #[test]
    fn error_frame_accessors() {
        let f = Frame::error(
            9, 4, ErrorCode::EpochMismatch, "plan moved",
            vec![("pinned", json::num(3.0)),
                 ("current", json::num(4.0))],
        );
        let out = roundtrip(&f);
        assert_eq!(out.error_code(), Some(ErrorCode::EpochMismatch));
        assert_eq!(out.message(), Some("plan moved"));
        assert_eq!(out.payload.req_f64("pinned").unwrap(), 3.0);
        assert_eq!(out.payload.req_f64("current").unwrap(), 4.0);
        assert!(ErrorCode::EpochMismatch.recoverable());
        assert!(!ErrorCode::BadFrame.recoverable());
        assert!(!ErrorCode::Oversized.recoverable());
        for c in 1..=9u16 {
            let code = ErrorCode::from_u16(c).unwrap();
            assert_eq!(code.as_u16(), c);
        }
        assert_eq!(ErrorCode::from_u16(0), None);
        assert_eq!(ErrorCode::from_u16(10), None);
    }
}
