//! Figure/table regeneration harnesses (paper §5 evaluation).
//!
//! Every table and figure in the paper's evaluation has a harness here,
//! shared by the CLI (`repro bench-*`) and the criterion benches:
//! * Table 2 — `repro stats` (dataset statistics)
//! * Fig 2   — [`fig2`]: per-epoch training time + inference latency,
//!   GNN-graph vs HAG, 2-layer GCN, 16 hidden dims, all five datasets
//! * Fig 3   — [`fig3`]: #aggregations + data transfers, normalized to
//!   the GNN-graph, with geometric mean (set and sequential modes)
//! * Fig 4   — [`fig4`]: capacity sweep vs per-epoch time on COLLAB,
//!   plus the §3.2 memory-overhead accounting
//!
//! Absolute numbers differ from the paper (V100/TensorFlow there, this
//! CPU testbed here); the *shape* — who wins and by roughly how much —
//! is the reproduction target. EXPERIMENTS.md records paper-vs-measured.

use std::path::Path;
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::{self, pack_workload, Repr};
use crate::datasets::{self, Dataset};
use crate::hag::{hag_search, AggregateKind, SearchConfig};
use crate::runtime::xla;
use crate::runtime::Runtime;
use crate::session::{LowerSpec, Session};

/// Per-dataset scale multiplier: REDDIT/COLLAB are far larger than the
/// rest; on the CPU testbed they run at a further-reduced scale so the
/// full figure regenerates in minutes. Documented in EXPERIMENTS.md.
pub fn effective_scale(name: &str, base: f64) -> f64 {
    match name.to_ascii_uppercase().as_str() {
        "REDDIT" => base * 0.2,
        "COLLAB" => base * 0.4,
        _ => base,
    }
}

fn dataset_list(names: Vec<String>) -> Vec<String> {
    if names.is_empty() {
        datasets::names().iter().map(|s| s.to_string()).collect()
    } else {
        names
    }
}

fn geo_mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

// ===================================================================
// Fig 3 — aggregation + data-transfer reductions (pure structure)
// ===================================================================

/// One dataset row of Fig 3.
#[derive(Debug, Clone)]
pub struct Fig3Row {
    pub dataset: String,
    pub aggregations_gnn: usize,
    pub aggregations_hag: usize,
    pub transfers_gnn: usize,
    pub transfers_hag: usize,
    pub agg_reduction: f64,
    pub transfer_reduction: f64,
    pub search_ms: f64,
}

/// Compute Fig 3 rows for all datasets under `kind`.
pub fn fig3_rows(kind: AggregateKind, base_scale: f64,
                 seed: u64) -> Vec<Fig3Row> {
    datasets::names()
        .iter()
        .map(|name| {
            let ds = datasets::load(name,
                                    effective_scale(name, base_scale),
                                    seed);
            let cfg = SearchConfig::paper_default(ds.graph.n())
                .with_kind(kind);
            let (_, stats) = hag_search(&ds.graph, &cfg);
            Fig3Row {
                dataset: name.to_string(),
                aggregations_gnn: stats.aggregations_before,
                aggregations_hag: stats.aggregations_after,
                transfers_gnn: stats.transfers_before,
                transfers_hag: stats.transfers_after,
                agg_reduction: stats.aggregations_before as f64
                    / stats.aggregations_after.max(1) as f64,
                transfer_reduction: stats.transfers_before as f64
                    / stats.transfers_after.max(1) as f64,
                search_ms: stats.elapsed_ms,
            }
        })
        .collect()
}

/// Print Fig 3 in the paper's normalized form.
pub fn fig3(kind: AggregateKind, base_scale: f64, seed: u64) -> Result<()> {
    println!("Fig 3 ({kind:?} AGGREGATE) — normalized to GNN-graph \
              (lower is better for HAG columns)");
    println!("{:<10} {:>14} {:>14} {:>12} {:>12} {:>10}", "dataset",
             "aggs (HAG/GNN)", "tx (HAG/GNN)", "agg x", "tx x",
             "search ms");
    let rows = fig3_rows(kind, base_scale, seed);
    for r in &rows {
        println!("{:<10} {:>14.3} {:>14.3} {:>11.2}x {:>11.2}x {:>10.1}",
                 r.dataset,
                 1.0 / r.agg_reduction,
                 1.0 / r.transfer_reduction,
                 r.agg_reduction, r.transfer_reduction, r.search_ms);
    }
    let ga = geo_mean(&rows.iter().map(|r| r.agg_reduction)
        .collect::<Vec<_>>());
    let gt = geo_mean(&rows.iter().map(|r| r.transfer_reduction)
        .collect::<Vec<_>>());
    println!("{:<10} {:>14.3} {:>14.3} {:>11.2}x {:>11.2}x", "geo-mean",
             1.0 / ga, 1.0 / gt, ga, gt);
    println!("paper ({:?}): aggregations 1.5-6.3x, transfers 1.3-5.6x \
              (set); up to 1.8x / 1.9x (sequential)", kind);
    Ok(())
}

// ===================================================================
// Fig 2 — end-to-end training + inference
// ===================================================================

#[derive(Debug, Clone)]
pub struct Fig2Row {
    pub dataset: String,
    pub train_ms_gnn: f64,
    pub train_ms_hag: f64,
    pub infer_ms_gnn: f64,
    pub infer_ms_hag: f64,
    pub train_speedup: f64,
    pub infer_speedup: f64,
}

/// Measure one dataset end-to-end under both representations.
pub fn fig2_row(artifacts: &Path, ds: &Dataset, seed: u64,
                epochs: usize) -> Result<Fig2Row> {
    let runtime = Arc::new(Runtime::open(artifacts)?);
    let mut train_ms = [0f64; 2];
    let mut infer_ms = [0f64; 2];
    for (i, repr) in [Repr::GnnGraph, Repr::Hag].into_iter().enumerate() {
        let lowered = Session::new(ds, LowerSpec::default()
            .with_repr(repr)).lower()?;
        let workload = pack_workload(ds, &lowered.plan, &lowered.bucket)?;
        // training
        let tname =
            coordinator::artifact_name("gcn", "train", &lowered.bucket);
        let mut trainer = coordinator::Trainer::new(
            runtime.clone(), &tname, &workload, seed)?;
        let report = trainer.train(epochs, 0)?;
        train_ms[i] = report.mean_epoch_ms;
        // inference (median of epochs executions)
        let iname =
            coordinator::artifact_name("gcn", "infer", &lowered.bucket);
        infer_ms[i] = measure_inference(&runtime, &iname, &workload,
                                        seed, epochs.max(5))?;
    }
    Ok(Fig2Row {
        dataset: ds.name.clone(),
        train_ms_gnn: train_ms[0],
        train_ms_hag: train_ms[1],
        infer_ms_gnn: infer_ms[0],
        infer_ms_hag: infer_ms[1],
        train_speedup: train_ms[0] / train_ms[1],
        infer_speedup: infer_ms[0] / infer_ms[1],
    })
}

/// Median full-graph inference latency for an artifact.
pub fn measure_inference(runtime: &Arc<Runtime>, artifact: &str,
                         workload: &coordinator::PackedWorkload,
                         seed: u64, repeats: usize) -> Result<f64> {
    let exe = runtime.compile(artifact)?;
    let param_specs: Vec<_> = exe.spec.inputs.iter()
        .filter(|s| !matches!(s.name.as_str(), "h0" | "deg")
                && !s.name.starts_with("lvl_")
                && !s.name.starts_with("band"))
        .cloned().collect();
    let params = coordinator::trainer::init_params(&param_specs, seed);
    let mut inputs = Vec::new();
    let mut pi = 0;
    for s in &exe.spec.inputs {
        if matches!(s.name.as_str(), "h0" | "deg")
            || s.name.starts_with("lvl_") || s.name.starts_with("band")
        {
            inputs.push(workload.get(&s.name).unwrap().clone());
        } else {
            inputs.push(params[pi].clone());
            pi += 1;
        }
    }
    let bufs = runtime.upload_checked(&exe, &inputs)?;
    let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
    let mut times = Vec::new();
    runtime.execute(&exe, &refs)?; // warmup
    for _ in 0..repeats {
        let t0 = std::time::Instant::now();
        runtime.execute(&exe, &refs)?;
        times.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Ok(times[times.len() / 2])
}

/// Print Fig 2 for the requested datasets.
pub fn fig2(artifacts: &Path, names: Vec<String>, base_scale: f64,
            seed: u64, epochs: usize) -> Result<()> {
    println!("Fig 2 — per-epoch training time + inference latency \
              (2-layer GCN, {} hidden dims)", coordinator::HIDDEN);
    println!("{:<10} {:>12} {:>12} {:>9} {:>12} {:>12} {:>9}", "dataset",
             "train gnn", "train hag", "speedup", "infer gnn",
             "infer hag", "speedup");
    let mut rows = Vec::new();
    for name in dataset_list(names) {
        let ds = datasets::load(&name,
                                effective_scale(&name, base_scale), seed);
        match fig2_row(artifacts, &ds, seed, epochs) {
            Ok(r) => {
                println!("{:<10} {:>10.1}ms {:>10.1}ms {:>8.2}x \
                          {:>10.1}ms {:>10.1}ms {:>8.2}x",
                         r.dataset, r.train_ms_gnn, r.train_ms_hag,
                         r.train_speedup, r.infer_ms_gnn, r.infer_ms_hag,
                         r.infer_speedup);
                rows.push(r);
            }
            Err(e) => println!("{name:<10} SKIPPED: {e:#}"),
        }
    }
    if !rows.is_empty() {
        let gt = geo_mean(&rows.iter().map(|r| r.train_speedup)
            .collect::<Vec<_>>());
        let gi = geo_mean(&rows.iter().map(|r| r.infer_speedup)
            .collect::<Vec<_>>());
        println!("{:<10} {:>12} {:>12} {:>8.2}x {:>12} {:>12} {:>8.2}x",
                 "geo-mean", "", "", gt, "", "", gi);
    }
    println!("paper: train up to 2.8x, inference up to 2.9x (V100)");
    Ok(())
}

// ===================================================================
// Fig 4 — capacity sweep (COLLAB)
// ===================================================================

/// Capacity fractions swept by Fig 4 (of |V|).
pub const FIG4_FRACTIONS: &[f64] = &[0.0, 0.03125, 0.0625, 0.125, 0.25];

#[derive(Debug, Clone)]
pub struct Fig4Row {
    pub capacity: usize,
    pub agg_nodes: usize,
    pub cost_core: usize,
    pub train_ms: Option<f64>,
    pub ahat_bytes: usize,
    pub plan_bytes: usize,
}

/// Bucket name for a Fig 4 sweep point.
pub fn fig4_bucket_name(frac: f64) -> String {
    format!("collab_cap{:04}", (frac * 10_000.0) as usize)
}

/// Compute (and if artifacts exist, measure) the Fig 4 sweep.
pub fn fig4_rows(artifacts: &Path, base_scale: f64, seed: u64,
                 epochs: usize) -> Result<Vec<Fig4Row>> {
    let ds = datasets::load("COLLAB",
                            effective_scale("COLLAB", base_scale), seed);
    let runtime = Runtime::open(artifacts).ok().map(Arc::new);
    let mut rows = Vec::new();
    for &frac in FIG4_FRACTIONS {
        let capacity = (ds.graph.n() as f64 * frac) as usize;
        let lowered = Session::new(&ds, LowerSpec::default()
            .with_capacity(capacity)).lower()?;
        let mut bucket = lowered.bucket.clone();
        bucket.name = fig4_bucket_name(frac);
        let tname = coordinator::artifact_name("gcn", "train", &bucket);
        let train_ms = match &runtime {
            Some(rt) if rt.spec(&tname).is_ok() => {
                let workload =
                    pack_workload(&ds, &lowered.plan, &bucket)?;
                let mut trainer = coordinator::Trainer::new(
                    rt.clone(), &tname, &workload, seed)?;
                Some(trainer.train(epochs, 0)?.mean_epoch_ms)
            }
            _ => None,
        };
        rows.push(Fig4Row {
            capacity,
            agg_nodes: lowered.hag.agg_nodes.len(),
            cost_core: lowered.hag.cost_core(),
            train_ms,
            ahat_bytes: lowered.hag
                .ahat_memory_bytes(coordinator::HIDDEN),
            plan_bytes: lowered.plan.plan_bytes(),
        });
    }
    Ok(rows)
}

/// Emit the Fig-4 sweep buckets into `buckets.json` (so `make
/// artifacts` builds them). Returns bucket specs.
pub fn fig4_buckets(base_scale: f64, seed: u64)
                    -> Result<Vec<crate::runtime::BucketSpec>> {
    let ds = datasets::load("COLLAB",
                            effective_scale("COLLAB", base_scale), seed);
    let mut out = Vec::new();
    for &frac in FIG4_FRACTIONS {
        let capacity = (ds.graph.n() as f64 * frac) as usize;
        let lowered = Session::new(&ds, LowerSpec::default()
            .with_capacity(capacity)).lower()?;
        let mut bucket = lowered.bucket;
        bucket.name = fig4_bucket_name(frac);
        out.push(bucket);
    }
    Ok(out)
}

/// Print Fig 4.
pub fn fig4(artifacts: &Path, base_scale: f64, seed: u64, epochs: usize,
            report_memory: bool) -> Result<()> {
    println!("Fig 4 — capacity sweep on COLLAB (per-epoch GCN training \
              time vs capacity)");
    println!("{:>10} {:>10} {:>12} {:>12} {:>14}", "capacity",
             "agg nodes", "cost |E|-|VA|", "train ms", "a-hat mem");
    let rows = fig4_rows(artifacts, base_scale, seed, epochs)?;
    let feat_bytes: usize = rows
        .first()
        .map(|_| {
            let ds = datasets::load(
                "COLLAB", effective_scale("COLLAB", base_scale), seed);
            ds.n() * coordinator::HIDDEN * 4 * 2 // 2 layers of h
        })
        .unwrap_or(1);
    for r in &rows {
        println!("{:>10} {:>10} {:>12} {:>12} {:>12.1}KB", r.capacity,
                 r.agg_nodes, r.cost_core,
                 r.train_ms.map(|t| format!("{t:.1}"))
                     .unwrap_or_else(|| "n/a".into()),
                 r.ahat_bytes as f64 / 1024.0);
    }
    if rows.iter().all(|r| r.train_ms.is_none()) {
        println!("(no fig4 artifacts found — run `repro emit-buckets` \
                  with fig4 sweep + `make artifacts` for timings; \
                  cost-model columns above are exact)");
    }
    if report_memory {
        let last = rows.last().unwrap();
        println!("\n§3.2 memory overhead at capacity |V|/4:");
        println!("  a-hat buffers : {:.1} KB ({:.3}% of activation \
                  memory {:.1} KB)",
                 last.ahat_bytes as f64 / 1024.0,
                 100.0 * last.ahat_bytes as f64 / feat_bytes as f64,
                 feat_bytes as f64 / 1024.0);
        println!("  plan tensors  : {:.1} KB", last.plan_bytes as f64
                 / 1024.0);
    }
    println!("paper: training time decreases monotonically with \
              capacity; best HAG ~150K agg nodes, 6MB (0.1% overhead), \
              2.8x speedup");
    Ok(())
}
