//! Flight recorder: post-mortem artifacts for serving failures.
//!
//! [`dump`] atomically writes (via [`crate::util::atomic_write`]'s
//! tmp + fsync + rename idiom) a timestamped JSON
//! file capturing the failure reason, the last
//! [`KEEP_EVENTS`] trace events across all threads, and a full
//! registry snapshot — turning a transient `[serve] batch failed`
//! stderr line into an artifact a human (or CI) can open after the
//! process is gone. Triggered on batch-execution failure, plan-swap
//! failure, and serving-contract trips.
//!
//! Destination: [`set_dir`] override (tests), else
//! `REPRO_FLIGHT_DIR`, else the OS temp dir. `REPRO_FLIGHT=0`
//! disables dumps entirely.
//!
//! Bounded: after every successful dump the destination directory is
//! rotated down to the newest [`DEFAULT_KEEP`] `obs-flight-*.json`
//! files (`REPRO_FLIGHT_KEEP` overrides), so a flapping swap path
//! cannot fill the disk with artifacts.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{SystemTime, UNIX_EPOCH};

use crate::obs::metrics::MetricsRegistry;
use crate::obs::trace;
use crate::util::json;

/// Most-recent trace events preserved per dump.
pub const KEEP_EVENTS: usize = 512;

/// Flight artifacts kept per directory after rotation
/// (`REPRO_FLIGHT_KEEP` overrides; values < 1 clamp to 1).
pub const DEFAULT_KEEP: usize = 16;

static DIR_OVERRIDE: Mutex<Option<PathBuf>> = Mutex::new(None);
static LAST: Mutex<Option<PathBuf>> = Mutex::new(None);
static SEQ: AtomicU64 = AtomicU64::new(0);

/// Route subsequent dumps to `dir` (tests; wins over the env var).
pub fn set_dir(dir: impl Into<PathBuf>) {
    *DIR_OVERRIDE.lock().unwrap() = Some(dir.into());
}

/// Path of the most recent dump this process wrote, if any. Dumps
/// happen on worker threads; callers (tests, shutdown paths) read
/// this after joining.
pub fn last_dump() -> Option<PathBuf> {
    LAST.lock().unwrap().clone()
}

/// Write a flight record; returns the path, or `None` when disabled
/// or the write failed (a failing failure-handler must never panic
/// the serving thread).
pub fn dump(reason: &str, registry: &MetricsRegistry)
            -> Option<PathBuf> {
    if std::env::var("REPRO_FLIGHT").is_ok_and(|v| v == "0") {
        return None;
    }
    let dir = DIR_OVERRIDE.lock().unwrap().clone()
        .or_else(|| std::env::var_os("REPRO_FLIGHT_DIR")
            .map(PathBuf::from))
        .unwrap_or_else(std::env::temp_dir);
    let ms = SystemTime::now().duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() as u64).unwrap_or(0);
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    let slug: String = reason.chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '-' })
        .collect();
    let path = dir.join(format!("obs-flight-{slug}-{ms}-{seq}.json"));

    let mut events = trace::collect();
    if events.len() > KEEP_EVENTS {
        events.drain(..events.len() - KEEP_EVENTS);
    }
    let doc = json::obj(vec![
        ("schema", json::str_("obs-flight-v1")),
        ("reason", json::str_(reason)),
        ("at_unix_ms", json::num(ms as f64)),
        ("snapshot", registry.snapshot().to_benchkit_value()),
        ("trace", trace::events_to_value(&events)),
    ]);

    let written = crate::util::atomic_write(
        &path, doc.to_string_pretty().as_bytes());
    match written {
        Ok(()) => {
            *LAST.lock().unwrap() = Some(path.clone());
            crate::obs_warn!("[obs] flight record ({reason}) -> {}",
                             path.display());
            let keep = std::env::var("REPRO_FLIGHT_KEEP").ok()
                .and_then(|v| v.parse::<usize>().ok())
                .unwrap_or(DEFAULT_KEEP);
            rotate(&dir, keep.max(1));
            Some(path)
        }
        Err(e) => {
            crate::obs_error!("[obs] flight record write failed: {e}");
            None
        }
    }
}

/// Delete all but the newest `keep` `obs-flight-*.json` files in
/// `dir`. "Newest" orders by the `-<unix_ms>-<seq>` filename suffix
/// (seq breaks same-millisecond ties), so rotation is stable across
/// processes and needs no fstat calls; unparseable names sort oldest.
/// Best-effort like the rest of the failure path: IO errors are
/// swallowed, never panics.
fn rotate(dir: &std::path::Path, keep: usize) {
    let Ok(rd) = std::fs::read_dir(dir) else { return };
    let mut files: Vec<(u64, u64, PathBuf)> = rd
        .filter_map(|e| e.ok())
        .filter_map(|e| {
            let path = e.path();
            let name = e.file_name();
            let name = name.to_str()?;
            if !name.starts_with("obs-flight-")
                || !name.ends_with(".json")
            {
                return None;
            }
            let stem = &name[..name.len() - ".json".len()];
            let mut it = stem.rsplitn(3, '-');
            let seq = it.next().and_then(|s| s.parse().ok())
                .unwrap_or(0u64);
            let ms = it.next().and_then(|s| s.parse().ok())
                .unwrap_or(0u64);
            Some((ms, seq, path))
        })
        .collect();
    if files.len() <= keep {
        return;
    }
    files.sort();
    let excess = files.len() - keep;
    for (_, _, path) in files.into_iter().take(excess) {
        let _ = std::fs::remove_file(path);
    }
}

/// Serializes tests that redirect the global dump dir via [`set_dir`]
/// (here and in the server's flight-record test): without it, a
/// concurrent override could route a dump into the other test's dir.
#[cfg(test)]
pub(crate) fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dump_writes_parseable_artifact_with_trace_and_snapshot() {
        let _guard = test_lock();
        let dir = std::env::temp_dir()
            .join(format!("repro-obs-flight-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        set_dir(&dir);
        trace::set_enabled(true);
        {
            let _s = crate::obs_span!("test.flight_span", 5u64);
        }
        let reg = MetricsRegistry::new();
        reg.counter("test.flight_counter").add(3);
        let path = dump("unit test", &reg).expect("dump written");
        // last_dump is global and other tests may dump concurrently;
        // just check the pointer is live
        assert!(last_dump().is_some());
        let text = std::fs::read_to_string(&path).unwrap();
        let v = crate::util::json::parse(&text).unwrap();
        assert_eq!(v.req_str("schema").unwrap(), "obs-flight-v1");
        assert_eq!(v.req_str("reason").unwrap(), "unit test");
        let snap = v.req("snapshot").unwrap();
        assert_eq!(snap.req("derived").unwrap()
                       .req_f64("test.flight_counter").unwrap(), 3.0);
        let evs = v.req_arr("trace").unwrap();
        assert!(evs.iter().any(|e| {
            e.req_str("name").unwrap() == "test.flight_span"
        }), "dump carries the recent span");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rotation_keeps_newest_n_and_ignores_foreign_files() {
        let dir = std::env::temp_dir().join(format!(
            "repro-obs-rotate-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        // 6 artifacts: 5 distinct timestamps plus a same-ms pair
        // where seq must break the tie
        for (ms, seq) in
            [(100u64, 0u64), (200, 1), (300, 2), (300, 3), (400, 4),
             (500, 5)]
        {
            std::fs::write(
                dir.join(format!("obs-flight-x-{ms}-{seq}.json")),
                "{}").unwrap();
        }
        // non-matching files must survive any rotation
        std::fs::write(dir.join("notes.json"), "{}").unwrap();
        std::fs::write(dir.join("obs-flight-keep.txt"), "").unwrap();
        rotate(&dir, 3);
        let mut left: Vec<String> = std::fs::read_dir(&dir).unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .collect();
        left.sort();
        assert_eq!(left, vec!["notes.json".to_string(),
                              "obs-flight-keep.txt".to_string(),
                              "obs-flight-x-300-3.json".to_string(),
                              "obs-flight-x-400-4.json".to_string(),
                              "obs-flight-x-500-5.json".to_string()]);
        // keep >= population: no-op
        rotate(&dir, 16);
        assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 5);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dump_applies_rotation_to_its_own_directory() {
        let _guard = test_lock();
        let dir = std::env::temp_dir().join(format!(
            "repro-obs-rotate-dump-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        set_dir(&dir);
        // pre-seed DEFAULT_KEEP stale artifacts with ancient stamps;
        // one real dump must displace the oldest
        for i in 0..DEFAULT_KEEP {
            std::fs::write(
                dir.join(format!("obs-flight-old-1-{i}.json")), "{}")
                .unwrap();
        }
        let reg = MetricsRegistry::new();
        dump("rotation probe", &reg).expect("dump written");
        let names: Vec<String> = std::fs::read_dir(&dir).unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .collect();
        assert_eq!(names.len(), DEFAULT_KEEP);
        assert!(!names.contains(&"obs-flight-old-1-0.json".into()),
                "oldest stale artifact rotated out: {names:?}");
        assert!(names.iter()
                    .any(|n| n.contains("rotation-probe")),
                "fresh dump kept: {names:?}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
