//! Metrics registry: named atomic counters, gauges, and fixed-bucket
//! log-scale latency histograms.
//!
//! The registry is the bounded-memory replacement for the serving
//! path's historical `Vec<f64>` latency accumulators: a histogram is
//! a fixed array of `AtomicU64` buckets, so a long-running server
//! costs O(1) memory per metric no matter how many requests it sees,
//! and percentiles are readable *live* (any thread may snapshot at
//! any time), not only at shutdown.
//!
//! ## Bucket scheme and error bound
//!
//! Values are recorded in integer nanoseconds. Buckets are exact
//! (width 1) below 64 ns; above that each power-of-two octave is
//! split into 64 sub-buckets (HdrHistogram-style top-6-mantissa
//! indexing), so the relative bucket width is at most 2^-6 ≈ 1.56%.
//! [`Histogram::percentile_ns`] keeps nearest-rank semantics (the
//! same rank rule as [`percentile_exact`]) and reports the midpoint
//! of the selected bucket, so the reported quantile is within a
//! **documented ≤ 2% relative error** of the exact nearest-rank
//! value (midpoint halves the 1.56% width to ≈ 0.78%; the 2% figure
//! leaves headroom for the clamped tail). Values above ~2^41 ns
//! (≈ 36 min) clamp into the last bucket.
//!
//! ## Naming
//!
//! Metric names follow `subsystem.noun_verb` (e.g.
//! `serve.plan_swaps`, `session.shard_cache_hits`); histograms name
//! the measured quantity (`serve.latency`, `serve.exec`). One scheme,
//! one formatter ([`StatsSnapshot::format`]), one export shape
//! ([`StatsSnapshot::to_benchkit_value`], benchkit-v1).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, OnceLock, RwLock};
use std::time::{Duration, SystemTime, UNIX_EPOCH};

use crate::util::json::Value;

/// Exact nearest-rank percentile over an ascending-sorted sample
/// (`p` in [0, 1]; NaN on empty input). This is the reference rule
/// the histogram approximates — the serving path used it directly on
/// unbounded vectors before the registry existed, and the histogram
/// unit tests compare against it.
pub fn percentile_exact(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let rank = (p * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Monotone counter handle (clone-cheap; all clones share storage).
#[derive(Clone, Debug, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Set-to-absolute gauge handle (publishes externally-owned stats
/// into a snapshot; may go down).
#[derive(Clone, Debug, Default)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, v: i64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

const SUB_BITS: u32 = 6;
const SUB: usize = 1 << SUB_BITS; // 64 sub-buckets per octave
const MAX_EXP: u32 = 41; // clamp above ~2^42 ns
const BUCKETS: usize = SUB + (MAX_EXP - SUB_BITS + 1) as usize * SUB;
const MAX_VAL: u64 = (1u64 << (MAX_EXP + 1)) - 1;

fn bucket_of(v: u64) -> usize {
    let v = v.min(MAX_VAL);
    if v < SUB as u64 {
        return v as usize;
    }
    let exp = 63 - v.leading_zeros(); // floor(log2 v), in SUB_BITS..=MAX_EXP
    let sub = ((v >> (exp - SUB_BITS)) & (SUB as u64 - 1)) as usize;
    SUB + (exp - SUB_BITS) as usize * SUB + sub
}

/// Inclusive-exclusive value range `[lo, hi)` covered by bucket `i`.
fn bucket_bounds(i: usize) -> (u64, u64) {
    if i < SUB {
        return (i as u64, i as u64 + 1);
    }
    let oct = ((i - SUB) / SUB) as u32; // exp - SUB_BITS
    let sub = ((i - SUB) % SUB) as u64;
    let width = 1u64 << oct;
    let lo = (SUB as u64 + sub) << oct;
    (lo, lo + width)
}

#[derive(Debug)]
struct HistCore {
    buckets: Box<[AtomicU64]>,
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64, // u64::MAX while empty
    max: AtomicU64,
}

/// Fixed-bucket log-scale histogram handle (nanosecond domain).
#[derive(Clone, Debug)]
pub struct Histogram(Arc<HistCore>);

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram(Arc::new(HistCore {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0))
                .collect::<Vec<_>>().into_boxed_slice(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }))
    }
}

impl Histogram {
    pub fn record_ns(&self, v: u64) {
        let c = &self.0;
        c.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        c.count.fetch_add(1, Ordering::Relaxed);
        c.sum.fetch_add(v, Ordering::Relaxed);
        c.min.fetch_min(v, Ordering::Relaxed);
        c.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn record(&self, d: Duration) {
        self.record_ns(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    /// Nearest-rank percentile in nanoseconds (bucket midpoint; see
    /// module docs for the ≤ 2% relative error bound). NaN on empty.
    pub fn percentile_ns(&self, p: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return f64::NAN;
        }
        let rank = ((p * n as f64).ceil() as u64).clamp(1, n);
        let mut seen = 0u64;
        for i in 0..BUCKETS {
            let c = self.0.buckets[i].load(Ordering::Relaxed);
            if c == 0 {
                continue;
            }
            seen += c;
            if seen >= rank {
                // integer domain: the bucket holds values in
                // [lo, hi-1], so this midpoint is exact for the
                // width-1 buckets below 64 ns
                let (lo, hi) = bucket_bounds(i);
                return (lo + hi - 1) as f64 / 2.0;
            }
        }
        // count raced ahead of a concurrent bucket write: the max is
        // the best remaining answer.
        self.0.max.load(Ordering::Relaxed) as f64
    }

    pub fn percentile_ms(&self, p: f64) -> f64 {
        self.percentile_ns(p) / 1.0e6
    }

    pub fn summary(&self) -> HistSummary {
        let count = self.count();
        let sum = self.0.sum.load(Ordering::Relaxed);
        let min = self.0.min.load(Ordering::Relaxed);
        HistSummary {
            count,
            mean_ns: if count == 0 { f64::NAN } else {
                sum as f64 / count as f64
            },
            min_ns: if min == u64::MAX { 0 } else { min },
            max_ns: self.0.max.load(Ordering::Relaxed),
            p50_ns: self.percentile_ns(0.50),
            p99_ns: self.percentile_ns(0.99),
        }
    }
}

/// Point-in-time digest of one histogram (times in nanoseconds).
#[derive(Debug, Clone, PartialEq)]
pub struct HistSummary {
    pub count: u64,
    pub mean_ns: f64,
    pub min_ns: u64,
    pub max_ns: u64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

#[derive(Clone, Debug)]
enum Metric {
    Counter(Counter),
    Gauge(Gauge),
    Hist(Histogram),
}

impl Metric {
    fn kind(&self) -> &'static str {
        match self {
            Metric::Counter(_) => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Hist(_) => "histogram",
        }
    }
}

/// Named metric store. Instantiable (each [`crate::coordinator`]
/// server owns one, so concurrently running servers — e.g. parallel
/// tests — never share counters), with a process-global instance for
/// CLI tools ([`MetricsRegistry::global`]). Handle lookup takes a
/// read lock once; hot paths cache the returned handle and pay one
/// relaxed atomic op per update after that.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    metrics: RwLock<BTreeMap<String, Metric>>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Process-global registry (CLI subcommands, ad-hoc probes).
    pub fn global() -> &'static MetricsRegistry {
        static GLOBAL: OnceLock<MetricsRegistry> = OnceLock::new();
        GLOBAL.get_or_init(MetricsRegistry::new)
    }

    fn get_or_insert(&self, name: &str,
                     make: impl FnOnce() -> Metric) -> Metric {
        if let Some(m) = self.metrics.read().unwrap().get(name) {
            return m.clone();
        }
        let mut w = self.metrics.write().unwrap();
        w.entry(name.to_string()).or_insert_with(make).clone()
    }

    /// Get or create a counter. Panics if `name` is already
    /// registered as a different metric kind (a naming bug, not a
    /// runtime condition).
    pub fn counter(&self, name: &str) -> Counter {
        match self.get_or_insert(name,
                                 || Metric::Counter(Counter::default()))
        {
            Metric::Counter(c) => c,
            m => panic!("metric {name:?} is a {}, not a counter",
                        m.kind()),
        }
    }

    pub fn gauge(&self, name: &str) -> Gauge {
        match self.get_or_insert(name,
                                 || Metric::Gauge(Gauge::default())) {
            Metric::Gauge(g) => g,
            m => panic!("metric {name:?} is a {}, not a gauge",
                        m.kind()),
        }
    }

    pub fn histogram(&self, name: &str) -> Histogram {
        match self.get_or_insert(name,
                                 || Metric::Hist(Histogram::default()))
        {
            Metric::Hist(h) => h,
            m => panic!("metric {name:?} is a {}, not a histogram",
                        m.kind()),
        }
    }

    /// Point-in-time snapshot of every registered metric. Cheap
    /// enough for periodic export; safe to call from any thread while
    /// writers are live (relaxed reads — each metric is internally
    /// consistent to within in-flight updates).
    pub fn snapshot(&self) -> StatsSnapshot {
        let at_unix_ms = SystemTime::now().duration_since(UNIX_EPOCH)
            .map(|d| d.as_millis() as u64).unwrap_or(0);
        let mut snap = StatsSnapshot {
            at_unix_ms,
            counters: BTreeMap::new(),
            gauges: BTreeMap::new(),
            hists: BTreeMap::new(),
        };
        for (name, m) in self.metrics.read().unwrap().iter() {
            match m {
                Metric::Counter(c) => {
                    snap.counters.insert(name.clone(), c.get());
                }
                Metric::Gauge(g) => {
                    snap.gauges.insert(name.clone(), g.get());
                }
                Metric::Hist(h) => {
                    snap.hists.insert(name.clone(), h.summary());
                }
            }
        }
        snap
    }
}

/// Plain-data snapshot (Send + Clone): what `ServerMsg::Stats`
/// returns over the channel API and what the periodic JSONL exporter
/// serializes.
#[derive(Debug, Clone, Default)]
pub struct StatsSnapshot {
    pub at_unix_ms: u64,
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, i64>,
    pub hists: BTreeMap<String, HistSummary>,
}

impl StatsSnapshot {
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> i64 {
        self.gauges.get(name).copied().unwrap_or(0)
    }

    pub fn hist(&self, name: &str) -> Option<&HistSummary> {
        self.hists.get(name)
    }

    /// benchkit-v1 document: histograms become `entries` rows (times
    /// in seconds, `iters` = sample count), counters/gauges/extra
    /// quantiles become `derived` scalars. Serialized through
    /// [`BenchJson`](crate::util::benchkit::BenchJson) — one schema,
    /// one emitter — so the bench harness and runtime telemetry can
    /// never drift apart (see EXPERIMENTS.md).
    pub fn to_benchkit_value(&self) -> Value {
        let ns_to_s = |ns: f64| if ns.is_nan() { 0.0 } else { ns / 1.0e9 };
        let mut bj = crate::util::benchkit::BenchJson::new();
        bj.derived_num("at_unix_ms", self.at_unix_ms as f64);
        for (name, h) in &self.hists {
            bj.push_entry(name, h.count, ns_to_s(h.p50_ns),
                          ns_to_s(h.mean_ns), h.min_ns as f64 / 1.0e9,
                          h.max_ns as f64 / 1.0e9);
            bj.derived_num(&format!("{name}.p99_s"),
                           ns_to_s(h.p99_ns));
        }
        for (name, v) in &self.counters {
            bj.derived_num(name, *v as f64);
        }
        for (name, v) in &self.gauges {
            bj.derived_num(name, *v as f64);
        }
        bj.to_value()
    }

    /// One human-readable line per metric (the single formatter the
    /// CLI and shutdown paths share).
    pub fn format(&self) -> String {
        fn ms(ns: f64) -> String {
            if ns.is_nan() {
                "-".to_string()
            } else {
                format!("{:.3}ms", ns / 1.0e6)
            }
        }
        let mut out = String::new();
        for (name, v) in &self.counters {
            out.push_str(&format!("counter {name:<34} {v}\n"));
        }
        for (name, v) in &self.gauges {
            out.push_str(&format!("gauge   {name:<34} {v}\n"));
        }
        for (name, h) in &self.hists {
            out.push_str(&format!(
                "hist    {name:<34} count {} p50 {} p99 {} mean {} \
                 max {}\n",
                h.count, ms(h.p50_ns), ms(h.p99_ns), ms(h.mean_ns),
                ms(h.max_ns as f64)));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn bucket_indexing_is_monotone_and_tight() {
        // exact below 64 ns
        for v in 0..64u64 {
            assert_eq!(bucket_of(v), v as usize);
            let (lo, hi) = bucket_bounds(bucket_of(v));
            assert!(lo <= v && v < hi);
        }
        // every bucket contains its value; bounds are contiguous and
        // within the documented relative width
        let mut prev = 0usize;
        for shift in 6..=MAX_EXP {
            for off in [0u64, 1, 63, 1 << (shift - 6)] {
                let v = (1u64 << shift) + off * (1 << (shift - 6));
                let v = v.min(MAX_VAL);
                let i = bucket_of(v);
                assert!(i >= prev, "monotone at v={v}");
                prev = i;
                let (lo, hi) = bucket_bounds(i);
                assert!(lo <= v && v < hi,
                        "v={v} not in [{lo},{hi}) (bucket {i})");
                assert!((hi - lo) as f64 / lo as f64
                            <= 1.0 / 64.0 + 1e-12,
                        "bucket {i} too wide");
            }
        }
        // clamp: everything above MAX_VAL lands in the last bucket
        assert_eq!(bucket_of(u64::MAX), BUCKETS - 1);
        assert_eq!(bucket_of(MAX_VAL), BUCKETS - 1);
    }

    #[test]
    fn histogram_percentiles_match_exact_within_2pct() {
        let mut rng = Rng::seed_from_u64(17);
        let h = Histogram::default();
        let mut exact: Vec<f64> = Vec::new();
        // log-uniform spread over ~9 decades: exercises linear
        // region, octave sub-buckets, and large values
        for _ in 0..20_000 {
            let e = rng.range_u32(0, 30);
            let base = 1u64 << e;
            let v = base
                + rng.range_usize(0, base.max(1) as usize) as u64;
            h.record_ns(v);
            exact.push(v as f64);
        }
        exact.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for p in [0.01, 0.25, 0.5, 0.9, 0.99, 0.999, 1.0] {
            let want = percentile_exact(&exact, p);
            let got = h.percentile_ns(p);
            let rel = (got - want).abs() / want.max(1.0);
            assert!(rel <= 0.02,
                    "p{p}: got {got}, want {want}, rel err {rel}");
        }
    }

    #[test]
    fn histogram_summary_tracks_count_min_max() {
        let h = Histogram::default();
        assert!(h.percentile_ns(0.5).is_nan());
        assert_eq!(h.summary().count, 0);
        for v in [5u64, 500, 50_000] {
            h.record_ns(v);
        }
        let s = h.summary();
        assert_eq!(s.count, 3);
        assert_eq!(s.min_ns, 5);
        assert_eq!(s.max_ns, 50_000);
        assert!(s.mean_ns > 0.0);
        // small values are exact (width-1 buckets)
        assert_eq!(h.percentile_ns(0.01), 5.0);
    }

    #[test]
    fn percentile_exact_is_nearest_rank() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_exact(&v, 0.5), 2.0);
        assert_eq!(percentile_exact(&v, 0.51), 3.0);
        assert_eq!(percentile_exact(&v, 0.0), 1.0);
        assert_eq!(percentile_exact(&v, 1.0), 4.0);
        assert!(percentile_exact(&[], 0.5).is_nan());
    }

    #[test]
    fn registry_handles_share_storage_and_snapshot() {
        let reg = MetricsRegistry::new();
        let c1 = reg.counter("t.reqs");
        let c2 = reg.counter("t.reqs");
        c1.inc();
        c2.add(2);
        assert_eq!(c1.get(), 3);
        reg.gauge("t.depth").set(-4);
        reg.histogram("t.lat").record(Duration::from_micros(250));
        let snap = reg.snapshot();
        assert_eq!(snap.counter("t.reqs"), 3);
        assert_eq!(snap.gauge("t.depth"), -4);
        assert_eq!(snap.hist("t.lat").unwrap().count, 1);
        assert_eq!(snap.counter("t.missing"), 0);
        // benchkit-v1 shape parses and carries the metrics
        let v = crate::util::json::parse(
            &snap.to_benchkit_value().to_string()).unwrap();
        assert_eq!(v.req_str("schema").unwrap(), "benchkit-v1");
        assert_eq!(v.req("derived").unwrap()
                       .req_f64("t.reqs").unwrap(), 3.0);
        let entries = v.req_arr("entries").unwrap();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].req_str("name").unwrap(), "t.lat");
        assert_eq!(entries[0].req_usize("iters").unwrap(), 1);
        // formatter covers every metric
        let text = snap.format();
        assert!(text.contains("t.reqs") && text.contains("t.depth")
                    && text.contains("t.lat"));
    }

    #[test]
    fn both_benchkit_producers_roundtrip_identically_shaped() {
        // Producer 1: the bench harness path (Duration domain).
        let b = crate::util::benchkit::Bencher {
            warmup: 0, iters: 3,
            max_total: Duration::from_secs(5),
        };
        let s = b.run("t.shape", || {
            std::hint::black_box(1 + 1);
        });
        let mut bj = crate::util::benchkit::BenchJson::new();
        bj.push(&s);
        bj.derived_num("at_unix_ms", 1.0);
        // Producer 2: the telemetry snapshot path (ns domain),
        // serialized through the same BenchJson emitter.
        let reg = MetricsRegistry::new();
        reg.histogram("t.shape").record(Duration::from_micros(80));
        let snap = reg.snapshot();
        let keys = |v: &Value| -> Vec<String> {
            match v.req_arr("entries").unwrap()[0] {
                Value::Obj(ref m) => m.keys().cloned().collect(),
                _ => panic!("entry is not an object"),
            }
        };
        for doc in [bj.to_value(), snap.to_benchkit_value()] {
            let v = crate::util::json::parse(&doc.to_string())
                .unwrap();
            assert_eq!(v.req_str("schema").unwrap(), "benchkit-v1");
            assert_eq!(v.req_arr("entries").unwrap().len(), 1);
            assert_eq!(keys(&v),
                       vec!["iters", "max_s", "mean_s", "median_s",
                            "min_s", "name"]);
            assert!(v.req("derived").unwrap()
                        .req_f64("at_unix_ms").unwrap() >= 1.0);
        }
        // snapshot-only extras ride in derived, same row shape
        let v = crate::util::json::parse(
            &snap.to_benchkit_value().to_string()).unwrap();
        assert!(v.req("derived").unwrap()
                    .req_f64("t.shape.p99_s").unwrap() > 0.0);
    }

    #[test]
    #[should_panic(expected = "not a counter")]
    fn kind_clash_panics() {
        let reg = MetricsRegistry::new();
        reg.gauge("t.oops");
        reg.counter("t.oops");
    }
}
