//! Observability substrate: metrics registry, event tracer, leveled
//! logger, and flight recorder (std-only; no external deps).
//!
//! Four pieces, one naming convention (`subsystem.noun_verb`):
//!
//! * [`metrics`] — named atomic counters, gauges, and fixed-bucket
//!   log-scale latency histograms ([`MetricsRegistry`]). Bounded
//!   memory per metric, live percentiles, benchkit-v1-compatible
//!   export ([`StatsSnapshot::to_benchkit_value`]).
//! * [`trace`] — lock-free per-thread ring-buffer spans/instants
//!   ([`obs_span!`]/[`obs_event!`]), dumpable as Chrome
//!   `trace_event` JSON. Disabled path is one relaxed atomic load.
//! * [`log`] — `REPRO_LOG=error|warn|info|trace` leveled stderr
//!   logger ([`obs_error!`]/[`obs_warn!`]/[`obs_info!`]/
//!   [`obs_trace!`]) with a capture sink for test assertions.
//! * [`flight`] — on serving failures, atomically dump the last N
//!   trace events + a registry snapshot to a timestamped file
//!   (rotated: newest [`flight::DEFAULT_KEEP`] per directory).
//! * [`cost`] — measured-vs-predicted Definition-2 cost audit:
//!   a bounded sample ring fitted online into live α̂/β̂
//!   ([`CostModel`]), calibrated-cost evaluation for drift policies
//!   ([`cost::calibrated_cost`]), and model-drift alerting. See
//!   DESIGN.md §11.
//!
//! Wiring map (who records what): the HAG search kernel spans its
//! merge rounds (`search.round`), the partitioned search spans each
//! shard (`partition.shard_search`), the session spans `plan()` and
//! marks shard cache hits/misses (`session.*`), the streaming engine
//! marks drift decisions and spans re-merges/rebuilds (`incr.*`),
//! and the inference server meters its whole request/update/swap
//! lifecycle (`serve.*`) against a per-server registry surfaced
//! live over `ServerMsg::Stats`. See DESIGN.md §10.

pub mod cost;
pub mod flight;
pub mod log;
pub mod metrics;
pub mod trace;

pub use cost::{Calibration, CostModel};
pub use log::Level;
pub use metrics::{Counter, Gauge, HistSummary, Histogram,
                  MetricsRegistry, StatsSnapshot};
pub use trace::{SpanGuard, TraceEvent};
