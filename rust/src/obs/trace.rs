//! Lock-free per-thread ring-buffer event tracer.
//!
//! Each thread that records a span or instant event owns a
//! fixed-size ring of seqlock-protected slots; rings register
//! themselves once (one mutex lock per thread lifetime) in a global
//! list so any thread can collect a best-effort snapshot of recent
//! events at any time — the shutdown trace dump and the flight
//! recorder both read live rings without stopping writers.
//!
//! The **disabled path is a few atomics, not a syscall**: every
//! [`obs_span!`]/[`obs_event!`] call site first does one relaxed
//! load of the global enable flag and returns immediately when
//! tracing is off (the `obs_overhead` bench pins a number on this).
//! When enabled, a record is one `Instant` read plus six relaxed
//! stores into the calling thread's own ring — no locks, no
//! allocation, no cross-thread contention.
//!
//! Event names are interned `&'static str`s (one `OnceLock<u32>` per
//! call site, filled on first use), following the same
//! `subsystem.noun_verb` convention as metric names. Dumps use the
//! Chrome `trace_event` JSON format (`chrome://tracing`, Perfetto):
//! spans are `"ph":"X"` complete events, instants are `"ph":"i"`.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Value;

/// Events kept per thread; older entries are overwritten in place.
const RING_CAP: usize = 4096;

pub const KIND_SPAN: u8 = 0;
pub const KIND_INSTANT: u8 = 1;

static ENABLED: AtomicBool = AtomicBool::new(false);

/// One relaxed load — the whole cost of a disabled trace point.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::SeqCst);
}

/// Process-wide time origin: all timestamps are microseconds since
/// the first trace call.
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

fn us_since_epoch(t: Instant) -> u64 {
    t.checked_duration_since(epoch())
        .map(|d| d.as_micros().min(u64::MAX as u128) as u64)
        .unwrap_or(0)
}

static NAMES: Mutex<Vec<&'static str>> = Mutex::new(Vec::new());

/// Intern an event name; call sites cache the returned id in a
/// `static OnceLock<u32>` (the macros below do this for you).
pub fn intern(name: &'static str) -> u32 {
    let mut v = NAMES.lock().unwrap();
    if let Some(i) = v.iter().position(|&n| n == name) {
        return i as u32;
    }
    v.push(name);
    (v.len() - 1) as u32
}

#[derive(Default)]
struct Slot {
    /// Seqlock word: 0 = never written, odd = write in progress,
    /// even = generation marker. Only the owning thread writes.
    seq: AtomicU64,
    ts_us: AtomicU64,
    dur_us: AtomicU64,
    meta: AtomicU64, // name_id << 8 | kind
    a: AtomicU64,
    b: AtomicU64,
}

struct Ring {
    tid: u64,
    head: AtomicU64,
    slots: Vec<Slot>,
}

impl Ring {
    fn push(&self, kind: u8, name_id: u32, ts_us: u64, dur_us: u64,
            a: u64, b: u64) {
        let idx = self.head.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[idx as usize % RING_CAP];
        let gen = idx / RING_CAP as u64;
        slot.seq.store(gen * 2 + 1, Ordering::Relaxed);
        slot.ts_us.store(ts_us, Ordering::Relaxed);
        slot.dur_us.store(dur_us, Ordering::Relaxed);
        slot.meta.store((name_id as u64) << 8 | kind as u64,
                        Ordering::Relaxed);
        slot.a.store(a, Ordering::Relaxed);
        slot.b.store(b, Ordering::Relaxed);
        slot.seq.store(gen * 2 + 2, Ordering::Release);
    }
}

static RINGS: Mutex<Vec<Arc<Ring>>> = Mutex::new(Vec::new());
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static RING: Arc<Ring> = {
        let ring = Arc::new(Ring {
            tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
            head: AtomicU64::new(0),
            slots: (0..RING_CAP).map(|_| Slot::default()).collect(),
        });
        RINGS.lock().unwrap().push(ring.clone());
        ring
    };
}

/// The calling thread's trace id (stable for the thread's lifetime;
/// tests use it to scope assertions to one worker's events).
pub fn current_tid() -> u64 {
    RING.with(|r| r.tid)
}

/// Record an instant event (no duration). No-op when disabled.
pub fn instant(name_id: u32, a: u64, b: u64) {
    if !enabled() {
        return;
    }
    let ts = us_since_epoch(Instant::now());
    RING.with(|r| r.push(KIND_INSTANT, name_id, ts, 0, a, b));
}

/// RAII span: records a complete event covering its lifetime when
/// dropped. Obtained via [`obs_span!`] (or [`span`] directly).
pub struct SpanGuard {
    name_id: u32,
    start: Option<Instant>, // None = tracing was off at entry
    a: u64,
    b: u64,
}

impl SpanGuard {
    pub fn disabled() -> SpanGuard {
        SpanGuard { name_id: 0, start: None, a: 0, b: 0 }
    }

    /// Update the args recorded at drop (e.g. counts only known at
    /// the end of the spanned region).
    pub fn set_args(&mut self, a: u64, b: u64) {
        self.a = a;
        self.b = b;
    }

    /// Suppress the span: drop records nothing. For call sites where
    /// only one outcome of the spanned region should appear in the
    /// trace (e.g. a plan swap that actually landed).
    pub fn cancel(&mut self) {
        self.start = None;
    }
}

pub fn span(name_id: u32, a: u64, b: u64) -> SpanGuard {
    if !enabled() {
        return SpanGuard::disabled();
    }
    SpanGuard { name_id, start: Some(Instant::now()), a, b }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(start) = self.start else {
            return;
        };
        let dur = start.elapsed()
            .as_micros().min(u64::MAX as u128) as u64;
        let ts = us_since_epoch(start);
        RING.with(|r| r.push(KIND_SPAN, self.name_id, ts, dur,
                             self.a, self.b));
    }
}

/// Open a named span over the enclosing scope.
/// `obs_span!("serve.batch", nodes)` — optional `a`/`b` args are
/// cast to `u64` and land in the Chrome trace's `args` object. Bind
/// the result (`let _span = obs_span!(..)`) or it drops immediately.
#[macro_export]
macro_rules! obs_span {
    ($name:literal) => { $crate::obs_span!($name, 0u64, 0u64) };
    ($name:literal, $a:expr) => { $crate::obs_span!($name, $a, 0u64) };
    ($name:literal, $a:expr, $b:expr) => {{
        if $crate::obs::trace::enabled() {
            static __OBS_ID: ::std::sync::OnceLock<u32> =
                ::std::sync::OnceLock::new();
            $crate::obs::trace::span(
                *__OBS_ID.get_or_init(
                    || $crate::obs::trace::intern($name)),
                ($a) as u64, ($b) as u64)
        } else {
            $crate::obs::trace::SpanGuard::disabled()
        }
    }};
}

/// Record a named instant event.
/// `obs_event!("serve.drift_check", due as u64)`.
#[macro_export]
macro_rules! obs_event {
    ($name:literal) => { $crate::obs_event!($name, 0u64, 0u64) };
    ($name:literal, $a:expr) => { $crate::obs_event!($name, $a, 0u64) };
    ($name:literal, $a:expr, $b:expr) => {{
        if $crate::obs::trace::enabled() {
            static __OBS_ID: ::std::sync::OnceLock<u32> =
                ::std::sync::OnceLock::new();
            $crate::obs::trace::instant(
                *__OBS_ID.get_or_init(
                    || $crate::obs::trace::intern($name)),
                ($a) as u64, ($b) as u64);
        }
    }};
}

/// A decoded trace record (snapshot copy, no atomics).
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub name: &'static str,
    pub kind: u8,
    pub tid: u64,
    pub ts_us: u64,
    pub dur_us: u64,
    pub a: u64,
    pub b: u64,
}

/// Best-effort snapshot of every thread's recent events, sorted by
/// timestamp. Slots that are mid-write when read (seqlock mismatch)
/// are skipped rather than surfaced torn.
pub fn collect() -> Vec<TraceEvent> {
    let rings: Vec<Arc<Ring>> = RINGS.lock().unwrap().clone();
    let names: Vec<&'static str> = NAMES.lock().unwrap().clone();
    let mut out = Vec::new();
    for ring in rings {
        for slot in &ring.slots {
            let s1 = slot.seq.load(Ordering::Acquire);
            if s1 == 0 || s1 % 2 == 1 {
                continue;
            }
            let meta = slot.meta.load(Ordering::Relaxed);
            let ev = TraceEvent {
                name: names.get((meta >> 8) as usize).copied()
                    .unwrap_or("?"),
                kind: (meta & 0xff) as u8,
                tid: ring.tid,
                ts_us: slot.ts_us.load(Ordering::Relaxed),
                dur_us: slot.dur_us.load(Ordering::Relaxed),
                a: slot.a.load(Ordering::Relaxed),
                b: slot.b.load(Ordering::Relaxed),
            };
            std::sync::atomic::fence(Ordering::Acquire);
            if slot.seq.load(Ordering::Relaxed) == s1 {
                out.push(ev);
            }
        }
    }
    out.sort_by_key(|e| (e.ts_us, e.tid));
    out
}

/// Chrome `trace_event` array for `events` (the `traceEvents` value).
pub fn events_to_value(events: &[TraceEvent]) -> Value {
    let rows = events.iter().map(|e| {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Value::Str(e.name.to_string()));
        m.insert("ph".to_string(),
                 Value::Str(if e.kind == KIND_SPAN { "X" } else { "i" }
                     .to_string()));
        m.insert("pid".to_string(), Value::Num(1.0));
        m.insert("tid".to_string(), Value::Num(e.tid as f64));
        m.insert("ts".to_string(), Value::Num(e.ts_us as f64));
        if e.kind == KIND_SPAN {
            m.insert("dur".to_string(), Value::Num(e.dur_us as f64));
        } else {
            // instant scope: thread
            m.insert("s".to_string(), Value::Str("t".to_string()));
        }
        let mut args = BTreeMap::new();
        args.insert("a".to_string(), Value::Num(e.a as f64));
        args.insert("b".to_string(), Value::Num(e.b as f64));
        m.insert("args".to_string(), Value::Obj(args));
        Value::Obj(m)
    }).collect();
    Value::Arr(rows)
}

/// Full Chrome trace document (`{"traceEvents": [...]}`).
pub fn dump_chrome_json() -> Value {
    let mut doc = BTreeMap::new();
    doc.insert("traceEvents".to_string(),
               events_to_value(&collect()));
    Value::Obj(doc)
}

pub fn write_chrome_trace(path: &Path) -> std::io::Result<()> {
    std::fs::write(path, dump_chrome_json().to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    // Tracing state is process-global; tests only ever turn it ON so
    // concurrently running tests cannot lose each other's events.

    #[test]
    fn spans_and_events_round_trip_through_collect() {
        set_enabled(true);
        let tid = current_tid();
        {
            let mut s = crate::obs_span!("test.outer", 7u64);
            s.set_args(7, 9);
            crate::obs_event!("test.mark", 3u64);
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let evs: Vec<TraceEvent> = collect().into_iter()
            .filter(|e| e.tid == tid).collect();
        let mark = evs.iter().find(|e| e.name == "test.mark")
            .expect("instant recorded");
        assert_eq!(mark.kind, KIND_INSTANT);
        assert_eq!((mark.a, mark.b), (3, 0));
        let outer = evs.iter().find(|e| e.name == "test.outer")
            .expect("span recorded");
        assert_eq!(outer.kind, KIND_SPAN);
        assert_eq!((outer.a, outer.b), (7, 9));
        assert!(outer.dur_us >= 1000, "span spans the sleep");
        // the span *starts* before the instant fires inside it
        assert!(outer.ts_us <= mark.ts_us);
    }

    #[test]
    fn chrome_dump_is_valid_json_with_phases() {
        set_enabled(true);
        {
            let _s = crate::obs_span!("test.chrome_span");
            crate::obs_event!("test.chrome_event");
        }
        let text = dump_chrome_json().to_string();
        let v = crate::util::json::parse(&text).unwrap();
        let rows = v.req_arr("traceEvents").unwrap();
        assert!(!rows.is_empty());
        for r in rows {
            let ph = r.req_str("ph").unwrap();
            assert!(ph == "X" || ph == "i");
            assert!(r.req_f64("ts").unwrap() >= 0.0);
            if ph == "X" {
                assert!(r.req_f64("dur").unwrap() >= 0.0);
            }
        }
        assert!(rows.iter().any(|r| {
            r.req_str("name").unwrap() == "test.chrome_span"
                && r.req_str("ph").unwrap() == "X"
        }));
    }

    #[test]
    fn cancelled_span_records_nothing() {
        set_enabled(true);
        let tid = current_tid();
        {
            let mut s = crate::obs_span!("test.cancelled");
            s.cancel();
        }
        assert!(!collect().iter().any(|e| {
            e.tid == tid && e.name == "test.cancelled"
        }));
    }

    #[test]
    fn interning_is_idempotent() {
        let a = intern("test.same");
        let b = intern("test.same");
        assert_eq!(a, b);
        assert_ne!(intern("test.other"), a);
    }

    #[test]
    fn ring_wraps_without_growing() {
        set_enabled(true);
        let tid = current_tid();
        for i in 0..(RING_CAP + 100) as u64 {
            crate::obs_event!("test.wrap", i);
        }
        let mine: Vec<TraceEvent> = collect().into_iter()
            .filter(|e| e.tid == tid && e.name == "test.wrap")
            .collect();
        assert!(mine.len() <= RING_CAP);
        // the newest event survived the wrap
        assert!(mine.iter()
            .any(|e| e.a == (RING_CAP + 100) as u64 - 1));
    }
}
