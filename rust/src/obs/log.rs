//! Tiny leveled logger honoring `REPRO_LOG=error|warn|info|trace`.
//!
//! Every former ad-hoc `eprintln!` in the serving/runtime/CLI paths
//! routes through [`obs_error!`]/[`obs_warn!`]/[`obs_info!`]/
//! [`obs_trace!`], so CI smoke output is controllable
//! (`REPRO_LOG=error` silences progress chatter) and tests can
//! assert on emitted warnings via the capture sink. The level is one
//! `AtomicU8` read per call site once the env var has been sampled;
//! disabled levels never format their arguments.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Trace = 3,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Trace => "trace",
        }
    }

    fn from_u8(v: u8) -> Level {
        match v {
            0 => Level::Error,
            1 => Level::Warn,
            2 => Level::Info,
            _ => Level::Trace,
        }
    }
}

const UNINIT: u8 = 255;
static LEVEL: AtomicU8 = AtomicU8::new(UNINIT);

fn level_from_env() -> Level {
    match std::env::var("REPRO_LOG").ok().as_deref()
        .map(str::to_ascii_lowercase).as_deref()
    {
        Some("error") => Level::Error,
        Some("warn") => Level::Warn,
        Some("trace") => Level::Trace,
        // "info", unknown values, and unset all mean the historical
        // default: everything the repo used to eprintln
        _ => Level::Info,
    }
}

/// Current level (samples `REPRO_LOG` on first use).
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        UNINIT => {
            let l = level_from_env();
            LEVEL.store(l as u8, Ordering::Relaxed);
            l
        }
        v => Level::from_u8(v),
    }
}

/// Override the level programmatically (tests, CLI flags). Wins over
/// the environment.
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

#[inline]
pub fn enabled(l: Level) -> bool {
    l <= level()
}

/// Test sink: while active, log lines are captured instead of
/// written to stderr. Global — keep begin/take pairs within one test
/// (see `tests` below for the pattern).
static CAPTURE: Mutex<Option<Vec<String>>> = Mutex::new(None);

pub fn capture_begin() {
    *CAPTURE.lock().unwrap() = Some(Vec::new());
}

pub fn capture_take() -> Vec<String> {
    CAPTURE.lock().unwrap().take().unwrap_or_default()
}

/// Sink for an already-level-checked record (use the macros, which
/// do the check without formatting).
pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let line = format!("[{}] {}", l.tag(), args);
    let mut cap = CAPTURE.lock().unwrap();
    if let Some(buf) = cap.as_mut() {
        buf.push(line);
    } else {
        drop(cap);
        eprintln!("{line}");
    }
}

#[macro_export]
macro_rules! obs_error {
    ($($t:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Error) {
            $crate::obs::log::log($crate::obs::log::Level::Error,
                                  format_args!($($t)*));
        }
    };
}

#[macro_export]
macro_rules! obs_warn {
    ($($t:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Warn) {
            $crate::obs::log::log($crate::obs::log::Level::Warn,
                                  format_args!($($t)*));
        }
    };
}

#[macro_export]
macro_rules! obs_info {
    ($($t:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Info) {
            $crate::obs::log::log($crate::obs::log::Level::Info,
                                  format_args!($($t)*));
        }
    };
}

#[macro_export]
macro_rules! obs_trace {
    ($($t:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Trace) {
            $crate::obs::log::log($crate::obs::log::Level::Trace,
                                  format_args!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    // The sink is global, so concurrently running tests (e.g. the
    // server tests, which warn on reference fallback) may interleave
    // lines: assertions filter on a marker unique to this test.
    #[test]
    fn levels_filter_and_capture_asserts_on_warnings() {
        let restore = level();
        capture_begin();
        set_level(Level::Warn);
        crate::obs_error!("obstest e {}", 1);
        crate::obs_warn!("obstest [serve] w {}", 2);
        crate::obs_info!("obstest i {}", 3);
        crate::obs_trace!("obstest t {}", 4);
        let got: Vec<String> = capture_take().into_iter()
            .filter(|l| l.contains("obstest")).collect();
        set_level(restore);
        assert_eq!(got, vec!["[error] obstest e 1".to_string(),
                             "[warn] obstest [serve] w 2".to_string()]);

        // raising to trace lets everything through
        capture_begin();
        set_level(Level::Trace);
        crate::obs_trace!("obstest deep");
        let got: Vec<String> = capture_take().into_iter()
            .filter(|l| l.contains("obstest")).collect();
        set_level(restore);
        assert_eq!(got, vec!["[trace] obstest deep".to_string()]);
    }

    #[test]
    fn level_ordering_matches_semantics() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Trace);
        assert_eq!(Level::from_u8(Level::Warn as u8), Level::Warn);
    }
}
