//! Cost-model audit: measured-vs-predicted Definition-2 cost, with
//! online α/β calibration (DESIGN.md §11).
//!
//! The paper's cost function (§4.1, Definition 2) prices a HAG as
//! `cost = α·aggregations + β·transfers`; the search only ever
//! minimizes `cost_core` (the α=β=1 point). This module makes the
//! model itself observable: a [`CostModel`] accumulates
//! `(aggregations, transfers) → measured_ns` samples from the host
//! reference executor into a bounded ring, fits live coefficient
//! estimates α̂/β̂ by incremental least-squares (running normal-
//! equation sums, closed-form 2×2 solve — std only), and reports a
//! windowed relative fit error. Consumers:
//!
//! * the serving path records one sample per executed batch and
//!   publishes the calibration into its [`MetricsRegistry`]
//!   ([`CostModel::publish`]: `cost.alpha`/`cost.beta`/
//!   `cost.model_error` gauges, fixed-point ×1e6);
//! * `DriftPolicy`'s fresh-cost comparison evaluates drift in
//!   calibrated units via [`calibrated_cost`] — the identity
//!   `Hag::cost(α,β) = α·cost_core + (β−α)·n` lets the streaming
//!   engine price its maintained HAG without materializing it;
//! * sustained fit error past the alert threshold emits [`obs_warn!`]
//!   and a flight record (`cost-model-drift`), so a cost model that
//!   stops tracking the hardware is an event, not a silent
//!   mis-optimization.
//!
//! Degenerate sample sets are expected and handled: a fixed serving
//! plan yields identical `(a, t)` rows (a singular system), and the
//! fit falls back to the combined-ratio estimate α̂ = β̂ — which makes
//! calibrated drift coincide with raw `cost_core` drift, the
//! conservative pre-calibration behavior. Distinguishing α from β
//! needs ratio diversity across plans (`repro cost-audit` and
//! `benches/cost_model.rs` sweep the generator corpus for exactly
//! that).
//!
//! [`obs_warn!`]: crate::obs_warn

use std::collections::VecDeque;
use std::sync::Mutex;

use crate::hag::Hag;
use crate::obs::flight;
use crate::obs::metrics::MetricsRegistry;

/// Fixed-point scale for float-valued gauges (`cost.alpha`,
/// `cost.beta`, `cost.model_error`): gauges are `i64`, so the
/// calibration exports as micro-units (value × 1e6).
pub const GAUGE_SCALE: f64 = 1e6;

/// Samples required before [`CostModel::calibration`] reports a fit.
pub const MIN_SAMPLES: usize = 8;

/// Default sample-ring capacity (the calibration window).
pub const DEFAULT_CAPACITY: usize = 256;

/// Default windowed-relative-error alert threshold (50%).
pub const DEFAULT_ALERT_ERROR: f64 = 0.5;

/// Consecutive over-threshold [`CostModel::publish`] observations
/// before the alert fires ("sustained", not a one-batch blip).
pub const DEFAULT_ALERT_STREAK: u32 = 8;

/// Recompute the running normal-equation sums from the ring after
/// this many recorded samples, bounding f64 add/subtract drift.
const RESUM_EVERY: u64 = 1024;

/// Calibrated Definition-2 cost from the two quantities every HAG
/// holder can produce cheaply: `Hag::cost(α, β) = α·(ê − |V_A|) +
/// (β − α)·|V| = α·cost_core + (β − α)·n`. Exact for any α/β (the
/// contract `prop_cost_identity` pins); at α=β=1 it is `cost_core`.
pub fn calibrated_cost(cost_core: usize, n: usize, alpha: f64,
                       beta: f64) -> f64 {
    alpha * cost_core as f64 + (beta - alpha) * n as f64
}

/// Record a plan's predicted Definition-2 terms as absolute gauges:
/// stitched totals (`cost.pred_aggregations`/`cost.pred_transfers`)
/// plus per-shard terms (`cost.shard<i>.pred_*`) when the caller has
/// them. Set-to-absolute, so re-recording after a swap is idempotent.
pub fn record_plan_terms(reg: &MetricsRegistry, hag: &Hag,
                         shards: &[(usize, usize)]) {
    reg.gauge("cost.pred_aggregations")
        .set(hag.aggregations() as i64);
    reg.gauge("cost.pred_transfers")
        .set(hag.data_transfers() as i64);
    for (i, &(aggs, transfers)) in shards.iter().enumerate() {
        reg.gauge(&format!("cost.shard{i}.pred_aggregations"))
            .set(aggs as i64);
        reg.gauge(&format!("cost.shard{i}.pred_transfers"))
            .set(transfers as i64);
    }
}

/// Attribute the *measured* tallies back to shards as
/// `cost.shard<i>.meas_aggregations`/`cost.shard<i>.meas_transfers`
/// gauges, next to the predicted ones [`record_plan_terms`] sets.
///
/// The stitched [`ExecutionPlan`](crate::hag::ExecutionPlan)
/// interleaves shards inside its level/band tensors (bands carry no
/// shard identity), so row-level measured attribution is not
/// recoverable post-stitch; instead the executor's cumulative
/// element-scaled tallies (`cost.meas_aggregations`/
/// `cost.meas_transfers`) are apportioned by each shard's share of
/// the predicted Definition-2 terms — cross-shard stitch edges and
/// padding land proportionally. The last shard absorbs integer
/// rounding, so the per-shard gauges always sum exactly to the
/// totals. Set-to-absolute and idempotent, like the predicted side.
pub fn record_shard_meas_terms(reg: &MetricsRegistry, meas_aggs: u64,
                               meas_transfers: u64,
                               shards: &[(usize, usize)]) {
    if shards.is_empty() {
        return;
    }
    let tot_a: usize = shards.iter().map(|s| s.0).sum();
    let tot_t: usize = shards.iter().map(|s| s.1).sum();
    let apportion = |total: u64, term: usize, sum: usize| -> u64 {
        if sum == 0 {
            // degenerate prediction (e.g. an edgeless shard set):
            // spread evenly rather than dropping the measurement
            total / shards.len() as u64
        } else {
            (total as f64 * term as f64 / sum as f64).round() as u64
        }
    };
    let (mut used_a, mut used_t) = (0u64, 0u64);
    let last = shards.len() - 1;
    for (i, &(aggs, transfers)) in shards.iter().enumerate() {
        let (a, t) = if i == last {
            (meas_aggs.saturating_sub(used_a),
             meas_transfers.saturating_sub(used_t))
        } else {
            let a = apportion(meas_aggs, aggs, tot_a)
                .min(meas_aggs - used_a);
            let t = apportion(meas_transfers, transfers, tot_t)
                .min(meas_transfers - used_t);
            (a, t)
        };
        used_a += a;
        used_t += t;
        reg.gauge(&format!("cost.shard{i}.meas_aggregations"))
            .set(a as i64);
        reg.gauge(&format!("cost.shard{i}.meas_transfers"))
            .set(t as i64);
    }
}

/// One executor observation: element-wise aggregation ops and operand
/// reads actually performed, and the wall time they took.
#[derive(Debug, Clone, Copy)]
struct Sample {
    aggs: f64,
    transfers: f64,
    ns: f64,
}

/// A point-in-time calibration readout.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Calibration {
    /// Fitted ns per aggregation op (α̂), clamped non-negative.
    pub alpha: f64,
    /// Fitted ns per transferred element (β̂), clamped non-negative.
    pub beta: f64,
    /// Mean relative residual `|α̂a + β̂t − y| / y` over the window.
    pub model_error: f64,
    /// Samples currently in the window.
    pub samples: usize,
}

struct Inner {
    ring: VecDeque<Sample>,
    capacity: usize,
    recorded: u64,
    // running normal-equation sums over the ring:
    // [saa sat; sat stt] [α; β] = [say; sty]
    saa: f64,
    sat: f64,
    stt: f64,
    say: f64,
    sty: f64,
    // alert state
    alert_error: f64,
    alert_streak: u32,
    streak: u32,
    alerted: bool,
}

impl Inner {
    fn push(&mut self, s: Sample) {
        if self.ring.len() == self.capacity {
            if let Some(old) = self.ring.pop_front() {
                self.saa -= old.aggs * old.aggs;
                self.sat -= old.aggs * old.transfers;
                self.stt -= old.transfers * old.transfers;
                self.say -= old.aggs * old.ns;
                self.sty -= old.transfers * old.ns;
            }
        }
        self.saa += s.aggs * s.aggs;
        self.sat += s.aggs * s.transfers;
        self.stt += s.transfers * s.transfers;
        self.say += s.aggs * s.ns;
        self.sty += s.transfers * s.ns;
        self.ring.push_back(s);
        self.recorded += 1;
        if self.recorded % RESUM_EVERY == 0 {
            self.resum();
        }
    }

    /// Rebuild the sums from the ring (bounds incremental f64 drift).
    fn resum(&mut self) {
        self.saa = 0.0;
        self.sat = 0.0;
        self.stt = 0.0;
        self.say = 0.0;
        self.sty = 0.0;
        for s in &self.ring {
            self.saa += s.aggs * s.aggs;
            self.sat += s.aggs * s.transfers;
            self.stt += s.transfers * s.transfers;
            self.say += s.aggs * s.ns;
            self.sty += s.transfers * s.ns;
        }
    }

    /// Closed-form least-squares solve of the 2×2 normal equations,
    /// with a combined-ratio fallback when the sample matrix is
    /// (near-)singular — identical `(a, t)` rows, e.g. a fixed
    /// serving plan — and non-negativity clamps refit on the
    /// remaining axis (a negative rate is never a usable price).
    fn fit(&self) -> Option<(f64, f64)> {
        if self.ring.len() < MIN_SAMPLES {
            return None;
        }
        let det = self.saa * self.stt - self.sat * self.sat;
        let scale = (self.saa * self.stt).max(1.0);
        if det.abs() > 1e-9 * scale {
            let alpha = (self.stt * self.say - self.sat * self.sty)
                / det;
            let beta = (self.saa * self.sty - self.sat * self.say)
                / det;
            if alpha >= 0.0 && beta >= 0.0 {
                return Some((alpha, beta));
            }
            if alpha < 0.0 && self.stt > 0.0 {
                return Some((0.0, (self.sty / self.stt).max(0.0)));
            }
            if beta < 0.0 && self.saa > 0.0 {
                return Some(((self.say / self.saa).max(0.0), 0.0));
            }
            return None;
        }
        // collinear: fit one shared rate r to y ≈ r·(a + t)
        let denom = self.saa + 2.0 * self.sat + self.stt;
        if denom <= 0.0 {
            return None;
        }
        let r = ((self.say + self.sty) / denom).max(0.0);
        Some((r, r))
    }

    fn calibration(&self) -> Option<Calibration> {
        let (alpha, beta) = self.fit()?;
        let mut err = 0.0;
        for s in &self.ring {
            let pred = alpha * s.aggs + beta * s.transfers;
            err += (pred - s.ns).abs() / s.ns.max(1.0);
        }
        Some(Calibration {
            alpha,
            beta,
            model_error: err / self.ring.len() as f64,
            samples: self.ring.len(),
        })
    }
}

/// Bounded-window online calibrator for the Definition-2 cost model.
/// Thread-safe (one mutex; callers record once per *batch*, not per
/// op, so contention is negligible next to an execute).
pub struct CostModel {
    inner: Mutex<Inner>,
}

impl Default for CostModel {
    fn default() -> CostModel {
        CostModel::new()
    }
}

impl CostModel {
    pub fn new() -> CostModel {
        CostModel::with_capacity(DEFAULT_CAPACITY)
    }

    pub fn with_capacity(capacity: usize) -> CostModel {
        CostModel {
            inner: Mutex::new(Inner {
                ring: VecDeque::with_capacity(capacity.max(1)),
                capacity: capacity.max(1),
                recorded: 0,
                saa: 0.0,
                sat: 0.0,
                stt: 0.0,
                say: 0.0,
                sty: 0.0,
                alert_error: DEFAULT_ALERT_ERROR,
                alert_streak: DEFAULT_ALERT_STREAK,
                streak: 0,
                alerted: false,
            }),
        }
    }

    /// Override the model-drift alert policy: fire after `streak`
    /// consecutive [`Self::publish`] observations with windowed error
    /// above `error`.
    pub fn set_alert(&self, error: f64, streak: u32) {
        let mut g = self.inner.lock().unwrap();
        g.alert_error = error;
        g.alert_streak = streak.max(1);
    }

    /// Record one measured batch: `aggs` element aggregation ops and
    /// `transfers` element operand reads took `ns` wall-nanoseconds.
    /// Zero-duration samples are dropped (a timer tick too coarse to
    /// price anything would only poison the fit).
    pub fn record_sample(&self, aggs: u64, transfers: u64, ns: u64) {
        if ns == 0 || (aggs == 0 && transfers == 0) {
            return;
        }
        self.inner.lock().unwrap().push(Sample {
            aggs: aggs as f64,
            transfers: transfers as f64,
            ns: ns as f64,
        });
    }

    /// Samples currently windowed.
    pub fn samples(&self) -> usize {
        self.inner.lock().unwrap().ring.len()
    }

    /// The live fit, or `None` before [`MIN_SAMPLES`] observations
    /// (or when the system is unfittable, e.g. all-zero operands).
    pub fn calibration(&self) -> Option<Calibration> {
        self.inner.lock().unwrap().calibration()
    }

    /// `(α̂, β̂)` for cost evaluation: the live fit when calibrated,
    /// else `(1, 1)` — the exact point where calibrated cost equals
    /// `cost_core`, so uncalibrated consumers behave as before.
    pub fn alpha_beta(&self) -> (f64, f64) {
        self.calibration().map_or((1.0, 1.0),
                                  |c| (c.alpha, c.beta))
    }

    /// Publish the calibration into `reg` (`cost.alpha`/`cost.beta`/
    /// `cost.model_error` fixed-point ×[`GAUGE_SCALE`],
    /// `cost.samples`, `cost.calibrated`) and run the sustained-error
    /// alert check: `alert_streak` consecutive publishes over
    /// `alert_error` emit one warn + flight record, re-armed once the
    /// error recovers below threshold.
    pub fn publish(&self, reg: &MetricsRegistry) {
        let (cal, fire, alert_error, alert_streak) = {
            let mut g = self.inner.lock().unwrap();
            let cal = g.calibration();
            let over = cal.map_or(false,
                                  |c| c.model_error > g.alert_error);
            let mut fire = false;
            if over {
                g.streak += 1;
                if g.streak >= g.alert_streak && !g.alerted {
                    g.alerted = true;
                    fire = true;
                }
            } else {
                g.streak = 0;
                g.alerted = false;
            }
            (cal, fire, g.alert_error, g.alert_streak)
        };
        let scaled = |v: f64| (v * GAUGE_SCALE).round() as i64;
        let (alpha, beta) = cal.map_or((1.0, 1.0),
                                       |c| (c.alpha, c.beta));
        reg.gauge("cost.alpha").set(scaled(alpha));
        reg.gauge("cost.beta").set(scaled(beta));
        reg.gauge("cost.model_error")
            .set(scaled(cal.map_or(0.0, |c| c.model_error)));
        reg.gauge("cost.samples")
            .set(cal.map_or(self.samples(), |c| c.samples) as i64);
        reg.gauge("cost.calibrated").set(cal.is_some() as i64);
        if fire {
            let c = cal.expect("alert implies a calibration");
            crate::obs_warn!(
                "[cost] model drift: windowed relative error \
                 {:.1}% > {:.1}% sustained over {} windows \
                 (alpha {:.4} beta {:.4} ns/elem, {} samples)",
                c.model_error * 100.0, alert_error * 100.0,
                alert_streak, c.alpha, c.beta, c.samples);
            flight::dump("cost-model-drift", reg);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;
    use crate::hag::AggregateKind;
    use crate::util::Rng;

    /// Noisy synthetic generator: y = α·a + β·t, ±`noise`
    /// multiplicative, over non-collinear (a, t) rows.
    fn feed(m: &CostModel, alpha: f64, beta: f64, noise: f64,
            samples: usize, seed: u64) {
        let mut rng = Rng::seed_from_u64(seed);
        for _ in 0..samples {
            let a = 1_000 + rng.range_usize(0, 50_000) as u64;
            let t = 1_000 + rng.range_usize(0, 80_000) as u64;
            let y = alpha * a as f64 + beta * t as f64;
            let eps = 1.0 + noise * (2.0 * rng.f64() - 1.0);
            m.record_sample(a, t, (y * eps) as u64);
        }
    }

    #[test]
    fn recovers_synthetic_coefficients_from_noisy_samples() {
        let m = CostModel::new();
        assert!(m.calibration().is_none(), "no fit before samples");
        feed(&m, 2.5, 0.8, 0.05, 200, 41);
        let c = m.calibration().expect("calibrated");
        assert!((c.alpha - 2.5).abs() / 2.5 < 0.10,
                "alpha {} vs 2.5", c.alpha);
        assert!((c.beta - 0.8).abs() / 0.8 < 0.10,
                "beta {} vs 0.8", c.beta);
        assert!(c.model_error < 0.10,
                "5% noise must fit well: err {}", c.model_error);
        assert_eq!(c.samples, DEFAULT_CAPACITY.min(200));
    }

    #[test]
    fn collinear_samples_fall_back_to_shared_rate() {
        let m = CostModel::new();
        // every row proportional to (2, 3): singular normal matrix
        for i in 1..40u64 {
            m.record_sample(2 * i * 100, 3 * i * 100,
                            i * 100 * (2 * 4 + 3 * 4));
        }
        let c = m.calibration().expect("calibrated");
        assert_eq!(c.alpha, c.beta, "fallback is a shared rate");
        assert!((c.alpha - 4.0).abs() < 0.2,
                "rate {} vs 4.0", c.alpha);
        // shared rate ⇒ calibrated drift degenerates to raw drift:
        // cost scales by a constant
        let x = calibrated_cost(100, 10, c.alpha, c.beta);
        let y = calibrated_cost(200, 10, c.alpha, c.beta);
        assert!((y / x - 2.0).abs() < 1e-9);
    }

    #[test]
    fn ring_is_bounded_and_fit_tracks_the_window() {
        let m = CostModel::with_capacity(32);
        feed(&m, 10.0, 10.0, 0.0, 100, 7);
        assert_eq!(m.samples(), 32);
        // drown the old regime: the window must forget it
        feed(&m, 1.0, 3.0, 0.0, 64, 8);
        let c = m.calibration().expect("calibrated");
        assert!((c.alpha - 1.0).abs() < 0.1, "alpha {}", c.alpha);
        assert!((c.beta - 3.0).abs() < 0.1, "beta {}", c.beta);
        assert!(c.model_error < 0.01);
    }

    #[test]
    fn shard_meas_attribution_sums_to_totals() {
        let reg = MetricsRegistry::new();
        // predicted shares 1:2:3 on aggs, 5:3:2 on transfers
        let shards = [(10, 50), (20, 30), (30, 20)];
        record_shard_meas_terms(&reg, 601, 1001, &shards);
        let a: i64 = (0..3).map(|i| reg
            .gauge(&format!("cost.shard{i}.meas_aggregations")).get())
            .sum();
        let t: i64 = (0..3).map(|i| reg
            .gauge(&format!("cost.shard{i}.meas_transfers")).get())
            .sum();
        assert_eq!(a, 601, "rounding never loses measured aggs");
        assert_eq!(t, 1001, "rounding never loses measured transfers");
        // proportionality: shard2 has 3x shard0's predicted aggs
        let a0 = reg.gauge("cost.shard0.meas_aggregations").get();
        let a2 = reg.gauge("cost.shard2.meas_aggregations").get();
        assert!((a2 as f64 / a0 as f64 - 3.0).abs() < 0.1,
                "shares follow prediction: {a0} vs {a2}");
        // degenerate all-zero prediction: even split, nothing dropped
        let reg2 = MetricsRegistry::new();
        record_shard_meas_terms(&reg2, 90, 7, &[(0, 0), (0, 0)]);
        assert_eq!(reg2.gauge("cost.shard0.meas_aggregations").get()
                   + reg2.gauge("cost.shard1.meas_aggregations").get(),
                   90);
        assert_eq!(reg2.gauge("cost.shard0.meas_transfers").get()
                   + reg2.gauge("cost.shard1.meas_transfers").get(),
                   7);
        // empty shard list is a no-op
        record_shard_meas_terms(&MetricsRegistry::new(), 5, 5, &[]);
    }

    #[test]
    fn calibrated_cost_matches_hag_cost() {
        let g = Graph::from_edges(5, &[(1, 0), (2, 0), (1, 3),
                                       (2, 3), (4, 2)]);
        let h = Hag::from_graph(&g, AggregateKind::Set);
        for (a, b) in [(1.0, 1.0), (2.5, 0.8), (0.0, 7.0)] {
            let want = h.cost(a, b);
            let got = calibrated_cost(h.cost_core(), h.n, a, b);
            assert!((got - want).abs() < 1e-9,
                    "cost({a},{b}): {got} != {want}");
        }
        assert_eq!(calibrated_cost(h.cost_core(), h.n, 1.0, 1.0),
                   h.cost_core() as f64);
    }

    #[test]
    fn publish_exports_gauges_and_sustained_error_alerts() {
        let _guard = flight::test_lock();
        let dir = std::env::temp_dir()
            .join(format!("repro-cost-alert-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        flight::set_dir(&dir);
        let reg = MetricsRegistry::new();
        let m = CostModel::new();
        m.publish(&reg);
        assert_eq!(reg.gauge("cost.calibrated").get(), 0);
        assert_eq!(reg.gauge("cost.alpha").get(),
                   GAUGE_SCALE as i64, "uncalibrated α defaults to 1");

        // a fit this bad trips any threshold: constant work, wildly
        // bimodal measured time
        for i in 0..20u64 {
            m.record_sample(1_000, 2_000,
                            if i % 2 == 0 { 1_000 } else { 400_000 });
        }
        m.set_alert(0.25, 3);
        crate::obs::log::capture_begin();
        m.publish(&reg); // streak 1
        m.publish(&reg); // streak 2
        m.publish(&reg); // streak 3: fires
        m.publish(&reg); // latched: no second record
        let warns: Vec<String> = crate::obs::log::capture_take()
            .into_iter()
            .filter(|l| l.contains("[cost] model drift"))
            .collect();
        assert_eq!(warns.len(), 1, "one sustained alert: {warns:?}");
        let dump = flight::last_dump().expect("flight record");
        assert!(dump.to_string_lossy().contains("cost-model-drift"),
                "dump {dump:?}");
        assert_eq!(reg.gauge("cost.calibrated").get(), 1);
        assert!(reg.gauge("cost.model_error").get()
                    > (0.25 * GAUGE_SCALE) as i64);
        std::fs::remove_dir_all(&dir).ok();
    }
}
