//! Serving example: spin up the inference server over the BZR stand-in
//! under both representations, drive it with concurrent client threads,
//! and report latency percentiles + throughput — the serving-path
//! counterpart of the Fig 2 inference comparison. A third section runs
//! **session-aware serving**: a resident `Session` rides in the
//! batcher, a shard-localized update stream dirties one shard, and the
//! drifted serving plan is hot-swapped from the per-shard plan cache.
//!
//! Runs everywhere: with compiled artifacts the batcher executes XLA;
//! without them it falls back to the host reference executor, so the
//! full request path (validation, batching, coalescing, swap) is
//! exercised on a fresh checkout too.
//!
//! ```bash
//! cargo run --release -- emit-buckets --datasets BZR --scale 0.05
//! make artifacts            # optional: XLA path
//! cargo run --release --example serve_inference
//! ```

use std::time::{Duration, Instant};

use repro::bench::effective_scale;
use repro::coordinator::{self, BatchPolicy, Repr, SwapPolicy};
use repro::datasets;
use repro::incremental::{DriftPolicy, GraphDelta};
use repro::session::{LowerSpec, Session};
use repro::util::Rng;

const SCALE: f64 = 0.05;
const SEED: u64 = 7;
const REQUESTS: usize = 400;
const CLIENTS: usize = 8;

fn main() -> anyhow::Result<()> {
    let ds = datasets::load("BZR", effective_scale("BZR", SCALE), SEED);
    println!("serving {} ({} nodes, {} edges)", ds.name, ds.n(), ds.e());

    for repr in [Repr::GnnGraph, Repr::Hag] {
        let lowered = Session::new(&ds, LowerSpec::default()
            .with_repr(repr)).lower()?;
        let server = coordinator::InferenceServer::for_lowered(
            "artifacts", "gcn", &ds, &lowered,
            BatchPolicy { max_batch: 64,
                          max_wait: Duration::from_millis(2) },
            SEED, None)?;
        let n = ds.n() as u32;
        let f_in = ds.f_in;
        let classes = ds.classes;
        let mut handles = Vec::new();
        for c in 0..CLIENTS {
            let tx = server.client();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::seed_from_u64(SEED + c as u64);
                for _ in 0..REQUESTS / CLIENTS {
                    let (otx, orx) = coordinator::server::oneshot();
                    let req = coordinator::ScoreRequest {
                        node: rng.range_u32(0, n),
                        features: (0..f_in)
                            .map(|_| rng.range_f32(-1.0, 1.0)).collect(),
                        reply: otx,
                        submitted: Instant::now(),
                        pin_epoch: None,
                    };
                    if tx.send(coordinator::ServerMsg::Score(req))
                        .is_err()
                    {
                        break;
                    }
                    let ok = orx.recv().expect("reply")
                        .into_result().expect("scored");
                    assert_eq!(ok.logits.len(), classes);
                }
            }));
        }
        for h in handles {
            h.join().expect("client thread");
        }
        let stats = server.shutdown();
        println!("\n[{:?}] {} requests in {} batches (mean {:.1}/batch)",
                 repr, stats.requests, stats.batches, stats.mean_batch);
        println!("  latency p50 {:.2} ms, p99 {:.2} ms; exec \
                  {:.2} ms/batch; {:.0} req/s",
                 stats.p50_ms, stats.p99_ms, stats.mean_exec_ms,
                 stats.throughput_rps);
    }

    // ---- session-aware serving: localized updates + hot plan swap.
    // A negative drift threshold forces the swap check at every
    // coalesced flush, so the demo is deterministic.
    println!("\n[session-aware serving] 4 shards, shard-0-localized \
              update stream");
    let spec = LowerSpec::default()
        .with_shards(4)
        .with_drift(DriftPolicy::default().with_threshold(-1.0));
    let mut session = Session::new(&ds, spec);
    let lowered = session.lower()?;
    let members: Vec<u32> = (0..ds.n() as u32)
        .filter(|&v| session.shard_of(v) == 0)
        .collect();
    let resident = coordinator::Resident::new(
        session, &ds.graph, &lowered.hag,
        SwapPolicy { swap_plans: true, max_pending: 8 });
    let server = coordinator::InferenceServer::for_lowered(
        "artifacts", "gcn", &ds, &lowered,
        BatchPolicy::default(), SEED, Some(resident))?;
    let tx = server.client();
    let mut rng = Rng::seed_from_u64(SEED ^ 0x5e55);
    for i in 0..200usize {
        if i % 4 == 0 && members.len() >= 2 {
            let a = members[rng.range_usize(0, members.len())];
            let b = members[rng.range_usize(0, members.len())];
            if a != b {
                let _ = tx.send(coordinator::ServerMsg::Update(
                    coordinator::UpdateRequest {
                        delta: GraphDelta::EdgeInsert { src: a, dst: b },
                        reply: None,
                        submitted: Instant::now(),
                    }));
            }
        }
        let (otx, orx) = coordinator::server::oneshot();
        let req = coordinator::ScoreRequest {
            node: rng.range_u32(0, ds.n() as u32),
            features: (0..ds.f_in)
                .map(|_| rng.range_f32(-1.0, 1.0)).collect(),
            reply: otx,
            submitted: Instant::now(),
            pin_epoch: None,
        };
        if tx.send(coordinator::ServerMsg::Score(req)).is_err() {
            break;
        }
        let _ = orx.recv().expect("reply").into_result()
            .expect("scored");
    }
    drop(tx);
    let out = server.shutdown_outcome();
    let s = &out.stats;
    println!("  {} requests; {} updates in {} flushes; {} plan swaps \
              ({} skipped)",
             s.requests, s.updates, s.update_batches, s.plan_swaps,
             s.swaps_skipped);
    println!("  session: {} shard re-searches, {} shard cache hits; \
              replan check {:?}",
             s.shard_searches, s.shard_cache_hits,
             s.plan_matches_fresh);
    assert_ne!(s.plan_matches_fresh, Some(false),
               "serving-path plan cache contract violated");
    Ok(())
}
