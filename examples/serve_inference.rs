//! Serving example: spin up the inference server over the BZR stand-in
//! under both representations, drive it with concurrent client threads,
//! and report latency percentiles + throughput — the serving-path
//! counterpart of the Fig 2 inference comparison.
//!
//! ```bash
//! cargo run --release -- emit-buckets --datasets BZR --scale 0.05
//! make artifacts
//! cargo run --release --example serve_inference
//! ```

use std::time::{Duration, Instant};

use repro::bench::effective_scale;
use repro::coordinator::{self, BatchPolicy, Repr};
use repro::datasets;
use repro::session::{LowerSpec, Session};
use repro::util::Rng;

const SCALE: f64 = 0.05;
const SEED: u64 = 7;
const REQUESTS: usize = 400;
const CLIENTS: usize = 8;

fn main() -> anyhow::Result<()> {
    let ds = datasets::load("BZR", effective_scale("BZR", SCALE), SEED);
    println!("serving {} ({} nodes, {} edges)", ds.name, ds.n(), ds.e());

    for repr in [Repr::GnnGraph, Repr::Hag] {
        let lowered = Session::new(&ds, LowerSpec::default()
            .with_repr(repr)).lower()?;
        let server = coordinator::InferenceServer::for_lowered(
            "artifacts", "gcn", &ds, &lowered,
            BatchPolicy { max_batch: 64,
                          max_wait: Duration::from_millis(2) },
            SEED, None)?;
        let n = ds.n() as u32;
        let f_in = ds.f_in;
        let classes = ds.classes;
        let mut handles = Vec::new();
        for c in 0..CLIENTS {
            let tx = server.client();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::seed_from_u64(SEED + c as u64);
                for _ in 0..REQUESTS / CLIENTS {
                    let (otx, orx) = coordinator::server::oneshot();
                    let req = coordinator::ScoreRequest {
                        node: rng.range_u32(0, n),
                        features: (0..f_in)
                            .map(|_| rng.range_f32(-1.0, 1.0)).collect(),
                        reply: otx,
                        submitted: Instant::now(),
                    };
                    if tx.send(coordinator::ServerMsg::Score(req))
                        .is_err()
                    {
                        break;
                    }
                    let resp = orx.recv().expect("reply");
                    assert_eq!(resp.logits.len(), classes);
                }
            }));
        }
        for h in handles {
            h.join().expect("client thread");
        }
        let stats = server.shutdown();
        println!("\n[{:?}] {} requests in {} batches (mean {:.1}/batch)",
                 repr, stats.requests, stats.batches, stats.mean_batch);
        println!("  latency p50 {:.2} ms, p99 {:.2} ms; exec \
                  {:.2} ms/batch; {:.0} req/s",
                 stats.p50_ms, stats.p99_ms, stats.mean_exec_ms,
                 stats.throughput_rps);
    }
    Ok(())
}
