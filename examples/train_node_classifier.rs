//! End-to-end driver (DESIGN.md §5 validation): generate the PPI
//! stand-in, search a HAG, train a 2-layer GCN to convergence under both
//! representations, log both loss curves, and verify they train to the
//! same quality while the HAG runs faster. This is the repo's
//! all-layers-compose proof: rust search/plan -> AOT XLA train step
//! (with Pallas kernels inside) -> rust epoch loop.
//!
//! ```bash
//! cargo run --release -- emit-buckets --datasets PPI --scale 0.05
//! make artifacts
//! cargo run --release --example train_node_classifier
//! ```

use std::sync::Arc;

use repro::bench::effective_scale;
use repro::coordinator::{self, Repr};
use repro::datasets;
use repro::runtime::Runtime;
use repro::session::{LowerSpec, Session};

const SCALE: f64 = 0.05;
const SEED: u64 = 7;
const EPOCHS: usize = 60;

fn main() -> anyhow::Result<()> {
    let ds = datasets::load("PPI", effective_scale("PPI", SCALE), SEED);
    println!("dataset: {} — {} nodes, {} edges, {} classes",
             ds.name, ds.n(), ds.e(), ds.classes);
    let runtime = Arc::new(Runtime::open("artifacts")?);

    let mut reports = Vec::new();
    for repr in [Repr::GnnGraph, Repr::Hag] {
        let lowered = Session::new(&ds, LowerSpec::default()
            .with_repr(repr)).lower()?;
        println!("\n=== {:?} ===", repr);
        println!("aggregations/layer: {}   transfers/layer: {}",
                 lowered.hag.aggregations(),
                 lowered.hag.data_transfers());
        let mut trainer = coordinator::Trainer::for_lowered(
            runtime.clone(), "gcn", &ds, &lowered, SEED)?;
        let report = trainer.train(EPOCHS, 10)?;
        println!("loss curve (every 10): {:?}",
                 report.epochs.iter().step_by(10)
                     .map(|e| (e.epoch, format!("{:.3}", e.loss)))
                     .collect::<Vec<_>>());
        println!("final: loss {:.4}, acc {:.3}, mean epoch {:.1} ms",
                 report.final_loss(), report.final_accuracy(),
                 report.mean_epoch_ms);
        reports.push(report);
    }

    let (gnn, hag) = (&reports[0], &reports[1]);
    println!("\n=== comparison ===");
    println!("train speedup (gnn/hag): {:.2}x",
             gnn.mean_epoch_ms / hag.mean_epoch_ms);
    println!("final loss: gnn {:.4} vs hag {:.4}", gnn.final_loss(),
             hag.final_loss());
    // Same-accuracy claim (§5.3): identical math => closely matching
    // training trajectories (init differs only through bucket shapes).
    let dl = (gnn.final_loss() - hag.final_loss()).abs();
    assert!(dl < 0.15, "loss divergence {dl} too large");
    assert!(hag.final_loss() < gnn.epochs[0].loss * 0.8,
            "training did not converge");
    println!("convergence + equivalence checks passed");
    Ok(())
}
