//! Quickstart: build a graph, search a HAG, verify equivalence, and run
//! one AOT-compiled GCN inference through the PJRT runtime.
//!
//! ```bash
//! make artifacts            # compiles the default `tiny*` buckets
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use repro::coordinator::trainer::init_params;
use repro::graph::Graph;
use repro::hag::{check_equivalence, AggregateKind, Hag, PlanConfig};
use repro::runtime::{HostTensor, Runtime};
use repro::session::{LowerSpec, Session};

fn main() -> anyhow::Result<()> {
    // --- 1. the paper's Fig 1 input graph -----------------------------
    let g = Graph::from_edges(
        5,
        &[
            (1, 0), (2, 0), (3, 0),           // A <- {B, C, D}
            (0, 1), (2, 1),                   // B <- {A, C}
            (0, 2), (1, 2), (4, 2),           // C <- {A, B, E}
            (1, 3), (2, 3),                   // D <- {B, C}
            (2, 4), (3, 4),                   // E <- {C, D}
        ],
    );
    println!("input graph: {} nodes, {} aggregation edges", g.n(), g.e());

    // --- 2. Algorithm 3, through a lowering session --------------------
    // The session owns search -> plan; `LowerSpec` is the one canonical
    // knob set (exact search here: unbounded capacity + pair window).
    let spec = LowerSpec::default()
        .with_capacity(usize::MAX)
        .with_pair_cap(usize::MAX)
        .with_plan(PlanConfig {
            br: 8, lvl_block: 128, max_bands: 1, nnzb_round: 16,
        });
    let mut session = Session::from_graph(&g, spec);
    let (hag, plan) = session.plan();
    let trivial = Hag::from_graph(&g, AggregateKind::Set);
    println!("HAG search: {} aggregation nodes, aggregations {} -> {}",
             hag.agg_nodes.len(), trivial.aggregations(),
             hag.aggregations());

    // --- 3. Theorem 1 equivalence --------------------------------------
    check_equivalence(&g, &hag).map_err(|e| anyhow::anyhow!(e))?;
    println!("equivalence: cover(v) == N(v) for all v  [Theorem 1] OK");

    // --- 4. execute through the AOT artifact ---------------------------
    // The `tiny4` bucket (n_pad=128, 4 levels) fits this plan.
    let runtime = Arc::new(Runtime::open("artifacts")?);
    let exe = runtime.compile("gcn_infer_tiny4")?;
    let b = &exe.spec.bucket;
    println!("artifact: {} (n_pad={}, levels={}, l_pad={})",
             exe.spec.name, b.n_pad, b.levels, b.l_pad);

    // pad plan tensors into the bucket's static shapes
    let zero = (b.m_pad() - 1) as i32;
    let remap = |x: i32| -> i32 {
        // plan zero-slot -> bucket zero-slot; level slots shift because
        // l_pad/levels may differ between the plan and the bucket
        if x as usize == plan.m_pad() - 1 {
            zero
        } else if (x as usize) < plan.n_pad {
            x
        } else {
            let off = x as usize - plan.n_pad;
            (b.n_pad + (off / plan.l_pad) * b.l_pad + off % plan.l_pad)
                as i32
        }
    };
    let mut lvl_left = vec![zero; b.levels * b.l_pad];
    let mut lvl_right = vec![zero; b.levels * b.l_pad];
    for l in 0..plan.levels {
        for j in 0..plan.l_pad.min(b.l_pad) {
            lvl_left[l * b.l_pad + j] =
                remap(plan.lvl_left[l * plan.l_pad + j]);
            lvl_right[l * b.l_pad + j] =
                remap(plan.lvl_right[l * plan.l_pad + j]);
        }
    }
    let (nb, nnzb) = b.bands[0];
    let mut col = vec![zero; nb * nnzb];
    let mut row = vec![0i32; nb * nnzb];
    let (pnb, pnnzb) = plan.bands[0];
    for blk in 0..pnb.min(nb) {
        for j in 0..pnnzb.min(nnzb) {
            col[blk * nnzb + j] =
                remap(plan.band_cols[0][blk * pnnzb + j]);
            row[blk * nnzb + j] = plan.band_rows[0][blk * pnnzb + j];
        }
    }

    // features: one-hot node id (f_in = 8)
    let f_in = b.f_in;
    let mut h0 = vec![0f32; b.n_pad * f_in];
    for v in 0..g.n() {
        let new = plan.inv_perm[v] as usize;
        h0[new * f_in + v % f_in] = 1.0;
    }
    let mut deg = vec![0f32; b.n_pad];
    deg[..plan.n_pad.min(b.n_pad)]
        .copy_from_slice(&plan.deg[..plan.n_pad.min(b.n_pad)]);

    let param_specs: Vec<_> = exe.spec.inputs.iter()
        .filter(|s| !matches!(s.name.as_str(), "h0" | "deg")
                && !s.name.starts_with("lvl_")
                && !s.name.starts_with("band"))
        .cloned().collect();
    let params = init_params(&param_specs, 42);
    let mut inputs = Vec::new();
    let mut pi = 0;
    for s in &exe.spec.inputs {
        inputs.push(match s.name.as_str() {
            "h0" => HostTensor::f32(h0.clone(), &[b.n_pad, f_in]),
            "deg" => HostTensor::f32(deg.clone(), &[b.n_pad]),
            "lvl_left" => HostTensor::i32(lvl_left.clone(),
                                          &[b.levels, b.l_pad]),
            "lvl_right" => HostTensor::i32(lvl_right.clone(),
                                           &[b.levels, b.l_pad]),
            "band0_col" => HostTensor::i32(col.clone(), &[nb, nnzb]),
            "band0_row" => HostTensor::i32(row.clone(), &[nb, nnzb]),
            _ => {
                pi += 1;
                params[pi - 1].clone()
            }
        });
    }
    let t0 = std::time::Instant::now();
    let outs = runtime.run(&exe.spec.name.clone(), &inputs)?;
    let logits = outs[0].as_f32()?;
    println!("inference ({} classes) in {:.2} ms:", b.classes,
             t0.elapsed().as_secs_f64() * 1e3);
    for v in 0..g.n() {
        let new = plan.inv_perm[v] as usize;
        let row = &logits[new * b.classes..(new + 1) * b.classes];
        println!("  node {v}: {row:?}");
    }
    println!("quickstart OK");
    Ok(())
}
