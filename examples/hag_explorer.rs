//! HAG explorer: the paper's §4 algorithmics on any dataset — runs the
//! search at several capacities and pair-cap settings, prints the cost
//! landscape, validates Theorem 1 at every point, compares against the
//! random-merge ablation baseline, shows the partitioned search
//! (`repro partition-stats` path): per-shard redundancy-elimination
//! stats, edge cut, and the sharded-vs-single cost gap and wall-clock
//! speedup — and closes with the incremental engine maintaining the
//! HAG through a random update stream (`repro stream` path).
//!
//! ```bash
//! cargo run --release --example hag_explorer -- BZR 0.05
//! ```

use repro::bench::effective_scale;
use repro::coordinator::random_merge_hag;
use repro::datasets;
use repro::hag::{check_equivalence_probabilistic, hag_search,
                 AggregateKind, SearchConfig};
use repro::incremental::{random_delta, OverlayGraph, StreamConfig,
                         StreamEngine};
use repro::partition::search_sharded;
use repro::session::{LowerSpec, Session};
use repro::util::Rng;

fn main() -> anyhow::Result<()> {
    let mut args = std::env::args().skip(1);
    let name = args.next().unwrap_or_else(|| "BZR".into());
    let base: f64 = args.next().map(|s| s.parse().unwrap())
        .unwrap_or(0.05);
    let ds = datasets::load(&name, effective_scale(&name, base), 7);
    println!("{} — {} nodes, {} edges", ds.name, ds.n(), ds.e());

    println!("\ncapacity sweep (set AGGREGATE):");
    println!("{:>10} {:>10} {:>12} {:>10} {:>10}", "capacity",
             "agg nodes", "aggregations", "reduction", "ms");
    let base_aggs = {
        let cfg = SearchConfig::paper_default(ds.graph.n())
            .with_capacity(0);
        hag_search(&ds.graph, &cfg).1.aggregations_before
    };
    for frac in [0.0, 0.05, 0.125, 0.25, 0.5] {
        let cap = (ds.graph.n() as f64 * frac) as usize;
        let cfg = SearchConfig::paper_default(ds.graph.n())
            .with_capacity(cap);
        let (hag, stats) = hag_search(&ds.graph, &cfg);
        check_equivalence_probabilistic(&ds.graph, &hag, 3)
            .map_err(|e| anyhow::anyhow!(e))?;
        println!("{:>10} {:>10} {:>12} {:>9.2}x {:>10.1}", cap,
                 stats.agg_nodes, stats.aggregations_after,
                 base_aggs as f64 / stats.aggregations_after.max(1) as f64,
                 stats.elapsed_ms);
    }

    println!("\nsequential AGGREGATE (prefix merging):");
    let cfg = SearchConfig::paper_default(ds.graph.n())
        .with_kind(AggregateKind::Sequential);
    let (_, stats) = hag_search(&ds.graph, &cfg);
    println!("  aggregations {} -> {} ({:.2}x), transfers {:.2}x",
             stats.aggregations_before, stats.aggregations_after,
             stats.aggregations_before as f64
                 / stats.aggregations_after.max(1) as f64,
             stats.transfers_before as f64
                 / stats.transfers_after.max(1) as f64);

    println!("\nablation — greedy (Algorithm 3) vs random merging:");
    let cap = ds.graph.n() / 4;
    let (greedy, gstats) = hag_search(
        &ds.graph,
        &SearchConfig::paper_default(ds.graph.n()).with_capacity(cap));
    let random = random_merge_hag(&ds.graph, cap, 99);
    check_equivalence_probabilistic(&ds.graph, &random, 4)
        .map_err(|e| anyhow::anyhow!(e))?;
    println!("  greedy: {:>10} aggregations ({} merges)",
             greedy.aggregations(), gstats.iterations);
    println!("  random: {:>10} aggregations ({} merges)",
             random.aggregations(), random.agg_nodes.len());
    println!("  greedy advantage: {:.2}x fewer",
             random.aggregations() as f64
                 / greedy.aggregations().max(1) as f64);

    println!("\npartitioned search (4 shards; see `repro \
              partition-stats` for the full report):");
    let cfg = SearchConfig::paper_default(ds.graph.n());
    let (sharded, sh) = search_sharded(&ds.graph, 4, &cfg);
    check_equivalence_probabilistic(&ds.graph, &sharded, 5)
        .map_err(|e| anyhow::anyhow!(e))?;
    println!("{:>6} {:>8} {:>12} {:>12} {:>10}", "shard", "nodes",
             "aggs gnn", "aggs hag", "ms");
    for (s, st) in sh.per_shard.iter().enumerate() {
        println!("{:>6} {:>8} {:>12} {:>12} {:>10.1}", s,
                 sh.report.shard_nodes[s], st.aggregations_before,
                 st.aggregations_after, st.elapsed_ms);
    }
    println!("  cut {:.1}%, cost {} vs single {} ({:+.2}%), wall \
              {:.1} ms on {} threads (single: {:.1} ms)",
             100.0 * sh.report.cut_frac, sharded.cost_core(),
             greedy.cost_core(),
             100.0 * (sharded.cost_core() as f64
                 / greedy.cost_core().max(1) as f64 - 1.0),
             sh.wall_ms, sh.threads, gstats.elapsed_ms);

    println!("\nstreaming maintenance (2000 random updates; `repro \
              stream` for the full report):");
    let mut scfg = StreamConfig::default();
    scfg.shards = 2;
    let mut eng = StreamEngine::new(&ds.graph, scfg);
    let mut rng = Rng::seed_from_u64(31);
    let mut lat_us: Vec<f64> = Vec::with_capacity(2000);
    for _ in 0..2000 {
        let d = random_delta(&mut rng, eng.overlay(), 0.5, 0.01);
        let t = std::time::Instant::now();
        eng.apply(d);
        lat_us.push(t.elapsed().as_secs_f64() * 1e6);
    }
    eng.finish_rebuild();
    let g_now = eng.graph();
    let maintained = eng.to_hag();
    check_equivalence_probabilistic(&g_now, &maintained, 6)
        .map_err(|e| anyhow::anyhow!(e))?;
    let t = std::time::Instant::now();
    let (fresh2, _) = hag_search(&g_now, &eng.search_config());
    let full_ms = t.elapsed().as_secs_f64() * 1e3;
    lat_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let s = eng.stats();
    println!("  {} fallbacks, {} re-merge merges, {} rebuilds; \
              repair p50 {:.1} us vs full re-search {:.1} ms",
             s.fallbacks, s.remerge_merges, s.rebuild_swaps,
             lat_us[lat_us.len() / 2], full_ms);
    println!("  cost {} vs fresh {} ({:+.2}%), graph now n={} e={}; \
              equivalence OK",
             maintained.cost_core(), fresh2.cost_core(),
             100.0 * (maintained.cost_core() as f64
                 / fresh2.cost_core().max(1) as f64 - 1.0),
             g_now.n(), g_now.e());

    println!("\nlowering session (4 shards; per-shard plan cache — \
              `repro stream --shards 4` for the full report):");
    let mut session =
        Session::new(&ds, LowerSpec::default().with_shards(4));
    let t = std::time::Instant::now();
    session.lower()?; // cold: search every shard + compile the plan
    let cold_ms = t.elapsed().as_secs_f64() * 1e3;
    // a short burst of updates, then a cached dirty-shard re-plan
    let mut mirror = OverlayGraph::new(ds.graph.clone());
    let mut srng = Rng::seed_from_u64(37);
    for _ in 0..16 {
        let d = random_delta(&mut srng, &mirror, 0.5, 0.0);
        mirror.apply(d);
        session.apply(d);
    }
    let dirty = session.dirty_shards();
    let t = std::time::Instant::now();
    let (hag_cached, plan_cached) = session.plan();
    let warm_ms = t.elapsed().as_secs_f64() * 1e3;
    let (hag_fresh, plan_fresh) = session.plan_fresh();
    let st = session.stats();
    println!("  cold lower {cold_ms:.1} ms; 16 updates left {dirty}/4 \
              shards dirty; re-plan {warm_ms:.1} ms \
              ({} shard searches total, {} cache hits)",
             st.shard_searches, st.shard_cache_hits);
    println!("  cached re-plan == from-scratch: {}",
             if *hag_cached == hag_fresh && *plan_cached == plan_fresh {
                 "OK"
             } else {
                 "MISMATCH"
             });
    Ok(())
}
