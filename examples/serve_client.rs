//! Wire-client example: the minimal blocking SDK
//! (`repro::net::Client`) against a live TCP front end.
//!
//! Two modes:
//!
//! - `--addr HOST:PORT` — connect to an already-running server (the
//!   CI smoke points this at `repro serve --listen 127.0.0.1:0`).
//! - no `--addr` — self-contained: spin up an in-process
//!   `InferenceServer` + `NetServer` on an ephemeral loopback port
//!   and talk to it over real TCP, so the example runs end-to-end on
//!   a fresh checkout with no second terminal.
//!
//! ```bash
//! cargo run --release --example serve_client                # spawn mode
//! cargo run --release --example serve_client -- --addr 127.0.0.1:4841
//! ```
//!
//! Exercises the whole client-visible contract: ping (epoch probe),
//! scoring with fresh feature rows, an epoch-pinned read, a
//! deliberately stale pin answered with `epoch_mismatch`, and a
//! stats snapshot over the wire.

use std::time::Duration;

use repro::net::{Client, NetConfig, NetServer, Outcome,
                 RetryPolicy};

fn parse_args() -> (Option<String>, usize) {
    let mut addr = None;
    let mut requests = 20usize;
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--addr" => addr = it.next(),
            "--requests" => {
                requests = it
                    .next()
                    .and_then(|v| v.parse().ok())
                    .expect("--requests N");
            }
            other => panic!("unknown arg {other:?} \
                             (usage: [--addr HOST:PORT] \
                             [--requests N])"),
        }
    }
    (addr, requests)
}

/// Spawn-mode backend: a small BZR stand-in behind the batcher and a
/// loopback TCP front end. Returns (net handle, inference server,
/// f_in, n) — the net handle must drain before the server shuts down.
fn spawn_local() -> anyhow::Result<(NetServer,
                                    repro::coordinator::InferenceServer,
                                    usize, u32)> {
    use repro::coordinator::{self, BatchPolicy};
    use repro::session::{LowerSpec, Session};

    let ds = repro::datasets::load("BZR", 0.02, 7);
    let lowered = Session::new(&ds, LowerSpec::default()).lower()?;
    let server = coordinator::InferenceServer::for_lowered(
        "artifacts", "gcn", &ds, &lowered,
        BatchPolicy { max_batch: 32,
                      max_wait: Duration::from_millis(2) },
        7, None)?;
    let reg = std::sync::Arc::new(
        repro::obs::metrics::MetricsRegistry::new());
    let net = NetServer::spawn("127.0.0.1:0", server.client(),
                               server.epoch_cell(), reg,
                               NetConfig::default())?;
    Ok((net, server, ds.f_in, ds.n() as u32))
}

fn main() -> anyhow::Result<()> {
    let (addr, requests) = parse_args();

    // Spawn-mode state kept alive for the whole run.
    let mut local = None;
    // In --addr mode the model's f_in is unknown, so requests keep
    // the resident feature rows (empty features = no replacement) and
    // stay in a small node range; out-of-range ids come back as
    // explicit rejections rather than failures either way.
    let (target, f_in, n) = match &addr {
        Some(a) => (a.clone(), 0usize, 16u32),
        None => {
            let (net, server, f_in, n) = spawn_local()?;
            let t = net.local_addr().to_string();
            println!("spawned in-process server on {t}");
            local = Some((net, server));
            (t, f_in, n)
        }
    };

    let mut client = Client::connect(&target)?;
    client.set_read_timeout(Duration::from_secs(10))?;

    // 1. Liveness + epoch probe.
    let epoch = client.ping()?;
    println!("ping       : serving plan epoch {epoch}");

    // 2. Scoring load with client-side latency accounting, through
    //    the retrying wrapper: transient admission sheds
    //    (retry_after / draining) are absorbed by capped jittered
    //    backoff honoring the server's hint, while semantic
    //    rejections (ids above the graph size come back as explicit
    //    node_out_of_range) surface immediately — count both.
    let retry = RetryPolicy::default();
    let mut lat_us: Vec<u64> = Vec::new();
    let (mut ok, mut rejected) = (0usize, 0usize);
    let mut state = 0x9e3779b97f4a7c15u64;
    let mut rand = move || {
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        state
    };
    for _ in 0..requests {
        let node = (rand() % n as u64) as u32;
        let features: Vec<f32> = (0..f_in)
            .map(|_| (rand() % 2000) as f32 / 1000.0 - 1.0)
            .collect();
        let t = std::time::Instant::now();
        match client.score_with_retry(node, &features, &retry)? {
            Outcome::Ok(score) => {
                ok += 1;
                lat_us.push(t.elapsed().as_micros() as u64);
                assert!(!score.logits.is_empty(), "empty logits");
                assert!(score.epoch >= 1, "epoch must start at 1");
            }
            Outcome::Rejected(rej) => {
                rejected += 1;
                println!("  rejected: {rej}");
            }
        }
    }
    lat_us.sort_unstable();
    if !lat_us.is_empty() {
        let p = |q: f64| {
            lat_us[((lat_us.len() - 1) as f64 * q) as usize]
        };
        println!("scores     : {ok} ok / {rejected} rejected; \
                  wire p50 {} us  p99 {} us", p(0.5), p(0.99));
    }

    // 3. Epoch pinning: a pin at the serving epoch answers; a stale
    //    pin must come back as a well-formed epoch_mismatch carrying
    //    both epochs — never a silent answer under the wrong plan.
    let now = client.ping()?;
    match client.score_pinned(0, &[], Some(now))? {
        Outcome::Ok(s) => {
            println!("pinned     : epoch {now} answered (epoch {})",
                     s.epoch);
        }
        Outcome::Rejected(rej) => {
            // Only a racing hot swap may reject a fresh pin.
            println!("pinned     : raced a swap ({rej})");
        }
    }
    match client.score_pinned(0, &[], Some(now + 1000))? {
        Outcome::Ok(_) => {
            anyhow::bail!("stale pin was silently answered");
        }
        Outcome::Rejected(rej) => {
            println!("stale pin  : {} (pinned {:?}, serving {:?})",
                     rej.code.name(), rej.pinned, rej.current);
            assert_eq!(rej.code.name(), "epoch_mismatch");
        }
    }

    // 4. Stats over the wire (benchkit-v1 document).
    if let Outcome::Ok(doc) = client.stats()? {
        let reqs = doc
            .get("derived")
            .and_then(|d| d.get("serve.requests"))
            .and_then(|v| v.as_f64())
            .unwrap_or(f64::NAN);
        println!("stats      : serve.requests {reqs}");
    }

    drop(client);
    if let Some((net, server)) = local {
        let ns = net.drain(Duration::from_secs(5));
        let stats = server.shutdown();
        println!("drained    : {} accepted, {} shed, {} drained; \
                  batcher saw {} requests",
                 ns.accepted, ns.shed, ns.drained, stats.requests);
    }
    println!("serve_client: OK");
    Ok(())
}
