//! haglint gate overhead: wall time of `analysis::verify` (the full
//! hag + plan + cost pass pipeline) and `verify_stitched` over the
//! generator corpus, reported per artifact size. The number that
//! matters operationally is verify-vs-plan-compile: the swap-path
//! gate runs at most once per accepted re-plan, so as long as
//! verification stays a small multiple of `build_plan` it is free in
//! context. Advisory — no hard threshold; shared runners are noisy.
//!
//! Run: `cargo bench --bench verify_overhead` (CI passes `--smoke`
//! for one bounded size). Results land in `BENCH_verify.json`
//! (override with `BENCH_JSON=...`) in the `benchkit-v1` schema.

use std::path::Path;

use repro::analysis::{self, corpus, HagCtx};
use repro::datasets::{community_graph, CommunityCfg};
use repro::hag::{build_plan, hag_search, AggregateKind, PlanConfig,
                 SearchConfig};
use repro::util::benchkit::{BenchJson, Bencher};

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let b = Bencher::quick();
    let mut json = BenchJson::new();

    // Size sweep: verify cost should track the artifact's edge count.
    let sizes: &[(usize, usize)] = if smoke {
        &[(400, 4_000)]
    } else {
        &[(400, 4_000), (1_600, 16_000), (6_400, 64_000)]
    };
    for &(n, e) in sizes {
        let cfg = CommunityCfg { n, e, communities: 8,
                                 intra_frac: 0.9, zipf_exp: 0.9,
                                 clone_frac: 0.5 };
        let (g, _) = community_graph(&cfg, 11);
        let scfg = SearchConfig { alpha: 1.0, beta: 1.0,
                                  capacity: usize::MAX,
                                  kind: AggregateKind::Set,
                                  pair_cap: usize::MAX };
        let (hag, _) = hag_search(&g, &scfg);
        let t0 = std::time::Instant::now();
        let plan = build_plan(&g, &hag, &PlanConfig::default());
        let compile_s = t0.elapsed().as_secs_f64();

        let s = b.run(&format!("verify_overhead/hag_plan_n{n}"), || {
            let ctx = HagCtx::new(&g, &hag).with_plan(&plan);
            let r = analysis::verify(&ctx);
            assert!(r.is_clean(), "{}", r.format());
        });
        let verify_s = s.median.as_secs_f64();
        json.push(&s);
        json.derived_num(&format!("verify_overhead/n{n}/verify_ms"),
                         verify_s * 1e3);
        json.derived_num(
            &format!("verify_overhead/n{n}/vs_plan_compile"),
            verify_s / compile_s.max(1e-9));
        println!("  n={n} e={e}: verify {:.3} ms, plan compile \
                  {:.3} ms ({:.2}x)",
                 verify_s * 1e3, compile_s * 1e3,
                 verify_s / compile_s.max(1e-9));
    }

    // The full corpus pass CI runs as its hard gate.
    let arts = corpus::corpus();
    let s = b.run("verify_overhead/corpus", || {
        for a in &arts {
            let r = a.verify();
            assert!(r.is_clean(), "{}: {}", a.name, r.format());
        }
    });
    json.push(&s);
    json.derived_num("verify_overhead/corpus/cases",
                     arts.len() as f64);
    json.derived_num("verify_overhead/corpus/ms",
                     s.median.as_secs_f64() * 1e3);
    println!("  corpus ({} artifacts): {:.1} ms/pass",
             arts.len(), s.median.as_secs_f64() * 1e3);

    let out = std::env::var("BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_verify.json".to_string());
    json.write(Path::new(&out))
        .unwrap_or_else(|e| panic!("writing {out}: {e}"));
    println!("wrote {out}");
}
