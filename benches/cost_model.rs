//! Cost-model audit bench: sweep the generator corpus through the
//! host reference executor (`coordinator::server::cost_probe`),
//! metering every batch into one online α̂/β̂ calibration, and
//! report Definition-2 predicted terms, executed (padded) op counts,
//! and per-dataset fit residuals.
//!
//! The interesting outputs are in `derived`:
//!
//! * `cost.alpha` / `cost.beta` — fitted ns per aggregation op /
//!   transferred element on this host;
//! * `cost.model_error` — mean relative residual over the sample
//!   window (the acceptance gate: ≤ 0.25 after warm-up);
//! * `cost_model/<ds>/residual` — per-dataset relative error of the
//!   fit replaying that dataset's own mean sample;
//! * `cost_model/<ds>/agg_overhead` — executed aggregation rows over
//!   the padding-free predicted count (what padding costs).
//!
//! Run: `cargo bench --bench cost_model`. Results land in
//! `BENCH_cost.json` (override with `BENCH_JSON=...`) in the
//! `benchkit-v1` schema; `repro obs --check-cost BENCH_cost.json`
//! validates the document.

use std::path::Path;
use std::sync::Arc;

use repro::coordinator::server::cost_probe;
use repro::datasets;
use repro::obs::CostModel;
use repro::util::benchkit::BenchJson;

const SCALE: f64 = 0.05;
const SEED: u64 = 7;
const BATCHES: usize = 12;
const HIDDEN: usize = 64;

fn main() {
    let model = Arc::new(CostModel::new());
    let mut json = BenchJson::new();
    let mut probes = Vec::new();

    // Warm-up pass: populate the window across plan shapes before
    // reading residuals, so the fit is over the full corpus.
    for name in datasets::names() {
        let ds = datasets::load(
            name, repro::bench::effective_scale(name, SCALE), SEED);
        let p = cost_probe(name, &ds.graph, ds.f_in, HIDDEN,
                           ds.classes, BATCHES, &model);
        println!(
            "bench cost_model/{:<28} pred aggs {:>10}  exec rows \
             {:>10}  overhead {:.2}x  exec mean {:.2} ms",
            p.name, p.pred_aggregations, p.plan_agg_rows,
            p.agg_overhead(), p.exec.mean_ns / 1e6);
        probes.push(p);
    }

    let cal = model.calibration()
        .expect("corpus sweep produces enough samples to calibrate");
    println!(
        "bench cost_model/calibration               alpha {:.4}  \
         beta {:.4} ns/elem  model error {:.1}%  ({} samples)",
        cal.alpha, cal.beta, 100.0 * cal.model_error, cal.samples);

    let mut sums = [0f64; 4];
    for p in &probes {
        json.push_entry(&format!("cost_model/{}", p.name),
                        p.exec.count, p.exec.p50_ns / 1e9,
                        p.exec.mean_ns / 1e9,
                        p.exec.min_ns as f64 / 1e9,
                        p.exec.max_ns as f64 / 1e9);
        // Fit residual replaying this dataset's mean sample: the
        // measured tallies are totals over `batches` executions and
        // the exec-mean is the whole forward, so rebuild the
        // per-batch aggregate-time prediction from the fit and
        // compare against what one batch actually measured.
        let aggs = p.meas_aggregations as f64 / p.batches as f64;
        let xfers = p.meas_transfers as f64 / p.batches as f64;
        let pred_ns = cal.alpha * aggs + cal.beta * xfers;
        let residual = if p.exec.mean_ns > 0.0 {
            // exec.mean includes the (untimed-by-the-model) matmuls,
            // so this is an upper bound on the aggregate-share error
            (pred_ns - p.exec.mean_ns).abs() / p.exec.mean_ns
        } else {
            0.0
        };
        let pre = format!("cost_model/{}", p.name);
        json.derived_num(&format!("{pre}/residual"), residual);
        json.derived_num(&format!("{pre}/agg_overhead"),
                         p.agg_overhead());
        json.derived_num(&format!("{pre}/pred_aggregations"),
                         p.pred_aggregations as f64);
        json.derived_num(&format!("{pre}/meas_aggregations"),
                         p.meas_aggregations as f64);
        sums[0] += p.pred_aggregations as f64;
        sums[1] += p.pred_transfers as f64;
        sums[2] += p.meas_aggregations as f64;
        sums[3] += p.meas_transfers as f64;
    }
    // The --check-cost contract keys, so CI validates this document
    // with the same gate as the serve sidecar.
    json.derived_num("cost.pred_aggregations", sums[0]);
    json.derived_num("cost.pred_transfers", sums[1]);
    json.derived_num("cost.meas_aggregations", sums[2]);
    json.derived_num("cost.meas_transfers", sums[3]);
    json.derived_num("cost.alpha", cal.alpha);
    json.derived_num("cost.beta", cal.beta);
    json.derived_num("cost.model_error", cal.model_error);
    json.derived_num("cost.samples", cal.samples as f64);
    json.derived_num("cost.calibrated", 1.0);

    let out = std::env::var("BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_cost.json".to_string());
    json.write(Path::new(&out))
        .unwrap_or_else(|e| panic!("writing {out}: {e}"));
    println!("wrote {out}");

    // Advisory gate (matches the ISSUE acceptance bar): warn loudly
    // instead of failing — shared CI runners time noisily.
    if cal.model_error > 0.25 {
        println!("advisory: model error {:.1}% exceeds the 25% \
                  acceptance bar", 100.0 * cal.model_error);
    }
}
