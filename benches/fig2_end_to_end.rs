//! Fig 2 bench: end-to-end per-epoch training time + inference latency
//! under both representations. Requires artifacts built for the default
//! bench configuration (`repro emit-buckets && make artifacts`);
//! datasets without artifacts are skipped with a notice.
//! Run: `cargo bench --bench fig2_end_to_end`.

use std::path::Path;
use std::sync::Arc;

use repro::bench::{effective_scale, measure_inference};
use repro::coordinator::{self, pack_workload, Repr};
use repro::datasets;
use repro::runtime::Runtime;
use repro::session::{LowerSpec, Session};
use repro::util::benchkit::Bencher;

const SCALE: f64 = 0.05;
const SEED: u64 = 7;

fn main() {
    let artifacts =
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let runtime = match Runtime::open(&artifacts) {
        Ok(rt) => Arc::new(rt),
        Err(e) => {
            eprintln!("[fig2] no artifacts ({e:#}); run `repro \
                       emit-buckets && make artifacts` first");
            return;
        }
    };
    let b = Bencher::quick();
    for name in datasets::names() {
        let ds =
            datasets::load(name, effective_scale(name, SCALE), SEED);
        let mut per_repr = [f64::NAN; 2];
        for (ri, repr) in
            [Repr::GnnGraph, Repr::Hag].into_iter().enumerate()
        {
            let lowered = Session::new(&ds, LowerSpec::default()
                .with_repr(repr)).lower().expect("lowering");
            let tname = coordinator::artifact_name("gcn", "train",
                                                   &lowered.bucket);
            if runtime.spec(&tname).is_err() {
                eprintln!("[fig2] skipping {tname}: artifact missing");
                continue;
            }
            let workload =
                pack_workload(&ds, &lowered.plan, &lowered.bucket)
                    .expect("packing");
            let mut trainer = coordinator::Trainer::new(
                runtime.clone(), &tname, &workload, SEED)
                .expect("trainer");
            trainer.step().expect("warmup");
            let stats = b.run(
                &format!("fig2_train/{}/{}", repr.tag(), name), || {
                    trainer.step().expect("step");
                });
            per_repr[ri] = stats.median.as_secs_f64() * 1e3;

            let iname = coordinator::artifact_name(
                "gcn", "infer", &lowered.bucket);
            if let Ok(ms) = measure_inference(&runtime, &iname,
                                              &workload, SEED, 5) {
                println!("  -> {} inference median {ms:.2} ms",
                         repr.tag());
            }
        }
        if per_repr.iter().all(|x| x.is_finite()) {
            println!("[fig2 {name}] train speedup (gnn/hag): {:.2}x",
                     per_repr[0] / per_repr[1]);
        }
    }
}
