//! Durability-plane overhead (DESIGN.md §14): WAL group-commit cost
//! per journaled delta, crash-recovery wall time as a function of
//! replay length, and the disarmed `fault::point` tax on the hot
//! path. The operational claims: journaling stays far below exec
//! cost per update batch, recovery scales linearly in the replayed
//! suffix (snapshots bound it), and a disarmed fault point costs a
//! few nanoseconds — cheap enough to leave compiled into production
//! binaries. Advisory — no hard threshold; shared runners are noisy.
//!
//! Run: `cargo bench --bench recovery` (CI passes `--smoke` for one
//! bounded replay length). Results land in `BENCH_recovery.json`
//! (override with `BENCH_JSON=...`) in the `benchkit-v1` schema.

use std::path::{Path, PathBuf};

use repro::durability::{recover, resume_pair, Wal};
use repro::graph::Graph;
use repro::incremental::{GraphDelta, StreamConfig, StreamEngine};
use repro::session::{LowerSpec, Session};
use repro::util::benchkit::{BenchJson, Bencher};

const BASE_N: u32 = 64;
const GROUP: usize = 64;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "repro-bench-recovery-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn base_graph() -> Graph {
    let edges: Vec<(u32, u32)> =
        (0..BASE_N).map(|i| (i, (i + 1) % BASE_N)).collect();
    Graph::from_edges(BASE_N as usize, &edges)
}

/// Valid unbounded history over the ring base: alternate NodeAdd
/// with an insert wiring the new node in, so every prefix replays.
fn delta_at(i: usize) -> GraphDelta {
    let k = (i / 2) as u32;
    if i % 2 == 0 {
        GraphDelta::NodeAdd
    } else {
        GraphDelta::EdgeInsert { src: k % BASE_N, dst: BASE_N + k }
    }
}

fn build_wal(dir: &Path, len: usize) {
    let mut w = Wal::open(dir, 1).unwrap();
    w.set_segment_bytes(1 << 20);
    for i in 0..len {
        w.append(delta_at(i)).unwrap();
        if i % GROUP == GROUP - 1 {
            w.commit().unwrap();
        }
    }
    if len % GROUP != 0 {
        w.commit().unwrap();
    }
}

fn dir_bytes(dir: &Path) -> u64 {
    std::fs::read_dir(dir).map(|rd| {
        rd.flatten()
            .filter_map(|e| e.metadata().ok())
            .map(|m| m.len())
            .sum()
    }).unwrap_or(0)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let b = Bencher::quick();
    let mut json = BenchJson::new();

    // Any REPRO_FAULTS in the environment would skew every number.
    repro::fault::reset();

    // 1) Journal cost: append + group-commit fsync, amortized per
    //    delta across a GROUP-sized batch (the serve-path shape).
    {
        let dir = tmpdir("append");
        let mut w = Wal::open(&dir, 1).unwrap();
        w.set_segment_bytes(8 << 20);
        let mut i = 0usize;
        let s = b.run("recovery/wal_group_commit_batch64", || {
            for _ in 0..GROUP {
                w.append(delta_at(i)).unwrap();
                i += 1;
            }
            w.commit().unwrap();
        });
        json.push(&s);
        let per = s.median.as_secs_f64() * 1e9 / GROUP as f64;
        json.derived_num("recovery/wal_append_ns_per_delta", per);
        println!("  wal group-commit: {:.0} ns/delta \
                  (batch of {GROUP}, fsync included)", per);
        drop(w);
        std::fs::remove_dir_all(&dir).ok();
    }

    // 2) Recovery wall time vs replay length: scan + CRC-validate
    //    the WAL, then replay into a fresh engine + session pair.
    let lens: &[usize] =
        if smoke { &[256] } else { &[256, 1_024, 4_096] };
    for &len in lens {
        let dir = tmpdir(&format!("replay{len}"));
        build_wal(&dir, len);
        let g = base_graph();
        let cfg = StreamConfig::default();
        let s = b.run(&format!("recovery/replay_{len}"), || {
            let rec = recover(&dir).expect("recover");
            assert_eq!(rec.deltas.len(), len);
            let mut engine = StreamEngine::new(&g, cfg.clone());
            let mut session =
                Session::from_graph(&g, LowerSpec::default());
            let rep = resume_pair(&rec, &mut engine, &mut session,
                                  &cfg).expect("replay");
            assert_eq!(rep.session_replayed, len);
        });
        json.push(&s);
        let ms = s.median.as_secs_f64() * 1e3;
        json.derived_num(&format!("recovery/replay_{len}/ms"), ms);
        json.derived_num(&format!("recovery/replay_{len}/wal_bytes"),
                         dir_bytes(&dir) as f64);
        json.derived_num(
            &format!("recovery/replay_{len}/ms_per_1k_deltas"),
            ms * 1e3 / len as f64);
        println!("  recover+replay {len} deltas: {ms:.2} ms \
                  ({:.2} ms/1k)", ms * 1e3 / len as f64);
        std::fs::remove_dir_all(&dir).ok();
    }

    // 3) Disarmed fault-point overhead: one relaxed atomic load per
    //    call. The acceptance target is single-digit nanoseconds.
    {
        let mut fired = 0u64;
        let s = b.run("recovery/fault_point_disarmed_x1000", || {
            for _ in 0..1_000 {
                if repro::fault::point("wal.append").is_err() {
                    fired += 1;
                }
            }
        });
        assert_eq!(fired, 0, "no fault is armed in this bench");
        json.push(&s);
        let ns = s.median.as_secs_f64() * 1e9 / 1_000.0;
        json.derived_num("recovery/fault_point_disarmed_ns", ns);
        println!("  disarmed fault::point: {ns:.1} ns/call");
    }

    let out = std::env::var("BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_recovery.json".to_string());
    json.write(Path::new(&out))
        .unwrap_or_else(|e| panic!("writing {out}: {e}"));
    println!("wrote {out}");
}
