//! Fig 4 bench: capacity sweep on the COLLAB stand-in. The cost-model
//! columns are exact; training timings additionally require the fig4
//! sweep artifacts (`repro emit-buckets --fig4` + `make artifacts`).
//! Run: `cargo bench --bench fig4_capacity`.

use std::path::Path;

use repro::bench::{effective_scale, fig4_rows, FIG4_FRACTIONS};
use repro::datasets;
use repro::session::{LowerSpec, Session};
use repro::util::benchkit::Bencher;

const SCALE: f64 = 0.02;
const SEED: u64 = 7;

fn main() {
    let ds = datasets::load("COLLAB", effective_scale("COLLAB", SCALE),
                            SEED);
    let b = Bencher::quick();
    for &frac in FIG4_FRACTIONS {
        let capacity = (ds.graph.n() as f64 * frac) as usize;
        let spec = LowerSpec::default().with_capacity(capacity);
        b.run(&format!("fig4_capacity_search/{capacity}"), || {
            // a fresh session per iteration: this row measures the
            // cold search+plan cost, not the session cache
            std::hint::black_box(
                Session::new(&ds, spec.clone()).lower().unwrap());
        });
    }

    // Print the cost sweep (and timings if artifacts exist).
    let artifacts =
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match fig4_rows(&artifacts, SCALE, SEED, 3) {
        Ok(rows) => {
            for r in rows {
                println!("[fig4] capacity {:>8}: agg_nodes {:>8}, cost \
                          {:>10}, train {:?} ms, a-hat {:.1} KB",
                         r.capacity, r.agg_nodes, r.cost_core,
                         r.train_ms, r.ahat_bytes as f64 / 1024.0);
            }
        }
        Err(e) => eprintln!("[fig4] sweep failed: {e:#}"),
    }
}
