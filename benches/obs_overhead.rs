//! Telemetry overhead micro-bench: ns/op for the obs primitives the
//! hot paths call. The contract the serving/search code relies on: a
//! *disabled* trace point costs one relaxed atomic load (plus loop
//! overhead here), and registry counters / histogram records stay in
//! the low-nanosecond range. CI prints these as an advisory guard —
//! no hard threshold, shared runners are too noisy for one.
//!
//! Run: `cargo bench --bench obs_overhead`. Besides the one-line
//! harness output, results land in `BENCH_obs.json` (override with
//! `BENCH_JSON=...`) in the `benchkit-v1` schema.

use std::path::Path;

use repro::obs::trace;
use repro::obs::MetricsRegistry;
use repro::util::benchkit::{BenchJson, BenchStats, Bencher};

/// Ops per timed closure call: each bench reports time / N.
const N: usize = 1_000_000;

fn ns_per_op(json: &mut BenchJson, s: &BenchStats) -> f64 {
    let ns = s.median.as_secs_f64() * 1e9 / N as f64;
    println!("  -> {ns:.2} ns/op");
    json.push(s);
    json.derived_num(&format!("{}/ns_per_op", s.name), ns);
    ns
}

fn main() {
    let b = Bencher::quick();
    let mut json = BenchJson::new();

    // Disabled tracing: the path every trace point takes in a normal
    // (untraced) run. This is the number that must stay trivial.
    // black_box sits outside the macros: their arg expressions only
    // evaluate when tracing is enabled, and the disabled loops must
    // not be deletable.
    trace::set_enabled(false);
    let s = b.run("obs_overhead/event_disabled", || {
        for i in 0..N {
            let i = std::hint::black_box(i);
            repro::obs_event!("bench.ev", i as u64);
        }
    });
    let ev_off = ns_per_op(&mut json, &s);
    let s = b.run("obs_overhead/span_disabled", || {
        for i in 0..N {
            let i = std::hint::black_box(i);
            let _sp = repro::obs_span!("bench.span", i as u64);
        }
    });
    let span_off = ns_per_op(&mut json, &s);

    // Enabled tracing: clock read + seqlock ring write per point.
    trace::set_enabled(true);
    let s = b.run("obs_overhead/event_enabled", || {
        for i in 0..N {
            let i = std::hint::black_box(i);
            repro::obs_event!("bench.ev", i as u64);
        }
    });
    ns_per_op(&mut json, &s);
    let s = b.run("obs_overhead/span_enabled", || {
        for i in 0..N {
            let i = std::hint::black_box(i);
            let _sp = repro::obs_span!("bench.span", i as u64);
        }
    });
    ns_per_op(&mut json, &s);
    trace::set_enabled(false);

    // Registry primitives: the batcher pays one of each per request.
    let reg = MetricsRegistry::new();
    let c = reg.counter("bench.count");
    let s = b.run("obs_overhead/counter_inc", || {
        for _ in 0..N {
            c.inc();
        }
    });
    ns_per_op(&mut json, &s);
    let h = reg.histogram("bench.lat");
    let s = b.run("obs_overhead/hist_record_ns", || {
        for i in 0..N {
            h.record_ns(std::hint::black_box(i) as u64);
        }
    });
    ns_per_op(&mut json, &s);

    println!(
        "advisory: disabled trace point {ev_off:.2} ns/event, \
         disabled span {span_off:.2} ns/span (target: a few atomics)");

    let out = std::env::var("BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_obs.json".to_string());
    json.write(Path::new(&out))
        .unwrap_or_else(|e| panic!("writing {out}: {e}"));
    println!("wrote {out}");
}
