//! HAG-search scaling bench (L3 hot path): edges/second across graph
//! sizes and pair-cap settings, plus the partitioned-search variant
//! (wall-clock speedup *and* cost gap per shard count — the speedup is
//! measured, not asserted; the partition-quality tradeoff is printed
//! next to it) and the session plan cache (dirty-shard re-plan vs
//! cold lowering). Run: `cargo bench --bench search_throughput`.

use repro::datasets::{community_graph, CommunityCfg};
use repro::hag::{hag_search, AggregateKind, SearchConfig};
use repro::incremental::GraphDelta;
use repro::partition::search_sharded;
use repro::session::{LowerSpec, Session};
use repro::util::benchkit::Bencher;

fn main() {
    let b = Bencher::quick();

    // scaling in |V| (constant average degree 20)
    for &n in &[1_000usize, 4_000, 16_000] {
        let cfg = CommunityCfg {
            n,
            e: n * 20,
            communities: (n / 160).max(4),
            intra_frac: 0.9,
            zipf_exp: 0.9,
            clone_frac: 0.5,
        };
        let (g, _) = community_graph(&cfg, 11);
        let edges = g.e();
        for kind in [AggregateKind::Set, AggregateKind::Sequential] {
            let sc = SearchConfig::paper_default(g.n()).with_kind(kind);
            let stats = b.run(
                &format!("search_scaling/{kind:?}/n{n}"), || {
                    std::hint::black_box(hag_search(&g, &sc));
                });
            let meps =
                edges as f64 / stats.median.as_secs_f64() / 1e6;
            println!("  -> {edges} edges, {meps:.2} Medges/s");
        }
    }

    // pair_cap ablation (search-space window vs quality/speed)
    let cfg = CommunityCfg {
        n: 8_000,
        e: 160_000,
        communities: 50,
        intra_frac: 0.9,
        zipf_exp: 1.0,
        clone_frac: 0.5,
    };
    let (g, _) = community_graph(&cfg, 13);
    for &cap in &[16usize, 32, 64, 128] {
        let mut sc = SearchConfig::paper_default(g.n());
        sc.pair_cap = cap;
        let (hag, _) = hag_search(&g, &sc);
        b.run(&format!("search_pair_cap/{cap}"), || {
            std::hint::black_box(hag_search(&g, &sc));
        });
        println!("  -> cost |E|-|VA| = {}", hag.cost_core());
    }

    // sharded search: wall-clock speedup + cost gap vs shard count
    // (the partition subsystem's headline tradeoff; the `1` row is the
    // single-threaded whole-graph baseline).
    let cfg = CommunityCfg {
        n: 16_000,
        e: 320_000,
        communities: 100,
        intra_frac: 0.9,
        zipf_exp: 0.9,
        clone_frac: 0.5,
    };
    let (g, _) = community_graph(&cfg, 17);
    let sc = SearchConfig::paper_default(g.n());
    let (single, _) = hag_search(&g, &sc);
    let base = b.run("search_sharded/1", || {
        std::hint::black_box(hag_search(&g, &sc));
    });
    for &k in &[2usize, 4, 8] {
        let (hag, stats) = search_sharded(&g, k, &sc);
        let run = b.run(&format!("search_sharded/{k}"), || {
            std::hint::black_box(search_sharded(&g, k, &sc));
        });
        let speedup = base.median.as_secs_f64()
            / run.median.as_secs_f64().max(1e-12);
        println!(
            "  -> {k} shards ({} threads): cost {} vs {} \
             ({:+.2}% gap), cut {:.1}%, speedup {speedup:.2}x",
            stats.threads, hag.cost_core(), single.cost_core(),
            100.0 * (hag.cost_core() as f64
                / single.cost_core().max(1) as f64 - 1.0),
            100.0 * stats.report.cut_frac);
    }

    // session plan cache: one delta dirties one shard; plan()
    // re-searches only that shard and splices the other three from
    // the cache. Compare against lowering a cold session each time.
    let spec = LowerSpec::default().with_shards(4);
    let mut session = Session::from_graph(&g, spec.clone());
    session.plan(); // warm the cache
    // toggle one intra-shard edge: bounded graph churn, exactly one
    // dirty shard per iteration
    let (mut eu, mut ev) = (0u32, 0u32);
    'find: for (v, ns) in g.iter() {
        for &u in ns {
            if session.shard_of(u) == session.shard_of(v) {
                eu = u;
                ev = v;
                break 'find;
            }
        }
    }
    let cold = b.run("session_plan/cold", || {
        std::hint::black_box(
            Session::from_graph(&g, spec.clone()).plan());
    });
    let mut present = true;
    let warm = b.run("session_plan/dirty_1_of_4", || {
        let d = if present {
            GraphDelta::EdgeDelete { src: eu, dst: ev }
        } else {
            GraphDelta::EdgeInsert { src: eu, dst: ev }
        };
        present = !present;
        assert!(session.apply(d));
        std::hint::black_box(session.plan());
    });
    let st = session.stats();
    println!(
        "  -> dirty-shard re-plan: {:.2}x faster than cold lowering \
         ({} shard re-searches, {} cache hits across {} plans)",
        cold.median.as_secs_f64()
            / warm.median.as_secs_f64().max(1e-12),
        st.shard_searches, st.shard_cache_hits, st.plans);
}
