//! HAG-search scaling bench (L3 hot path): edges/second across graph
//! sizes and pair-cap settings, the flat-kernel vs retained-reference
//! comparison (the PR-5 rewrite's headline number: same byte-identical
//! merge order, hash maps and per-round rebuilds gone), plus the
//! partitioned-search variant (wall-clock speedup *and* cost gap per
//! shard count — the speedup is measured, not asserted; the
//! partition-quality tradeoff is printed next to it) and the session
//! plan cache (dirty-shard re-plan vs cold lowering).
//!
//! Run: `cargo bench --bench search_throughput`. Besides the one-line
//! harness output, results land in `BENCH_search.json` (override the
//! path with `BENCH_JSON=...`) in the `benchkit-v1` schema, so the
//! perf trajectory EXPERIMENTS.md tracks is machine-diffable.

use std::path::Path;

use repro::datasets::{community_graph, CommunityCfg};
use repro::hag::{hag_search, hag_search_reference,
                 hag_search_with_scratch, AggregateKind, SearchConfig,
                 SearchScratch};
use repro::incremental::GraphDelta;
use repro::partition::search_sharded;
use repro::session::{LowerSpec, Session};
use repro::util::benchkit::{BenchJson, Bencher};

fn main() {
    let b = Bencher::quick();
    let mut json = BenchJson::new();

    // scaling in |V| (constant average degree 20)
    for &n in &[1_000usize, 4_000, 16_000] {
        let cfg = CommunityCfg {
            n,
            e: n * 20,
            communities: (n / 160).max(4),
            intra_frac: 0.9,
            zipf_exp: 0.9,
            clone_frac: 0.5,
        };
        let (g, _) = community_graph(&cfg, 11);
        let edges = g.e();
        for kind in [AggregateKind::Set, AggregateKind::Sequential] {
            let sc = SearchConfig::paper_default(g.n()).with_kind(kind);
            let stats = b.run(
                &format!("search_scaling/{kind:?}/n{n}"), || {
                    std::hint::black_box(hag_search(&g, &sc));
                });
            let meps =
                edges as f64 / stats.median.as_secs_f64() / 1e6;
            println!("  -> {edges} edges, {meps:.2} Medges/s");
            json.push(&stats);
            json.derived_num(
                &format!("search_scaling/{kind:?}/n{n}/medges_per_s"),
                meps);
        }
    }

    // pair_cap ablation (search-space window vs quality/speed)
    let cfg = CommunityCfg {
        n: 8_000,
        e: 160_000,
        communities: 50,
        intra_frac: 0.9,
        zipf_exp: 1.0,
        clone_frac: 0.5,
    };
    let (g, _) = community_graph(&cfg, 13);
    for &cap in &[16usize, 32, 64, 128] {
        let mut sc = SearchConfig::paper_default(g.n());
        sc.pair_cap = cap;
        let (hag, _) = hag_search(&g, &sc);
        let stats = b.run(&format!("search_pair_cap/{cap}"), || {
            std::hint::black_box(hag_search(&g, &sc));
        });
        println!("  -> cost |E|-|VA| = {}", hag.cost_core());
        json.push(&stats);
        json.derived_num(&format!("search_pair_cap/{cap}/cost_core"),
                         hag.cost_core() as f64);
    }

    // The largest generator graph, reused by the kernel comparison
    // and the sharded sweep below.
    let cfg = CommunityCfg {
        n: 16_000,
        e: 320_000,
        communities: 100,
        intra_frac: 0.9,
        zipf_exp: 0.9,
        clone_frac: 0.5,
    };
    let (g, _) = community_graph(&cfg, 17);
    let sc = SearchConfig::paper_default(g.n());

    // flat kernel vs retained naive reference (single shard,
    // paper-default config): the two produce byte-identical HAGs —
    // asserted here at bench scale on top of the differential tests —
    // so the ratio is a pure data-layout speedup. Acceptance target:
    // >= 2x on this graph.
    let (h_ref, s_ref) = hag_search_reference(&g, &sc);
    let (h_new, s_new) = hag_search(&g, &sc);
    assert_eq!(h_ref.agg_nodes, h_new.agg_nodes,
               "kernel diverged from reference merge order");
    assert_eq!(h_ref.in_edges, h_new.in_edges,
               "kernel diverged from reference final lists");
    let reference = b.run("search_kernel/reference", || {
        std::hint::black_box(hag_search_reference(&g, &sc));
    });
    let flat = b.run("search_kernel/flat", || {
        std::hint::black_box(hag_search(&g, &sc));
    });
    let mut scratch = SearchScratch::new();
    hag_search_with_scratch(&g, &sc, &mut scratch); // warm the arena
    let reused = b.run("search_kernel/flat_scratch_reuse", || {
        std::hint::black_box(
            hag_search_with_scratch(&g, &sc, &mut scratch));
    });
    let speedup = reference.median.as_secs_f64()
        / flat.median.as_secs_f64().max(1e-12);
    println!(
        "  -> flat kernel {speedup:.2}x vs reference (byte-identical \
         HAG: {} agg nodes); {} rounds, {} pops ({} stale), scratch \
         {:.1} KiB; reuse {:.2}x vs reference",
        h_new.agg_nodes.len(), s_new.rounds, s_new.heap_pops,
        s_new.stale_pops, s_new.peak_scratch_bytes as f64 / 1024.0,
        reference.median.as_secs_f64()
            / reused.median.as_secs_f64().max(1e-12));
    let _ = s_ref;
    json.push(&reference);
    json.push(&flat);
    json.push(&reused);
    json.derived_num("search_kernel/speedup_vs_reference", speedup);
    json.derived_num("search_kernel/rounds", s_new.rounds as f64);
    json.derived_num("search_kernel/heap_pops",
                     s_new.heap_pops as f64);
    json.derived_num("search_kernel/stale_pops",
                     s_new.stale_pops as f64);
    json.derived_num("search_kernel/peak_scratch_bytes",
                     s_new.peak_scratch_bytes as f64);
    json.derived_num("search_kernel/graph_nodes", g.n() as f64);
    json.derived_num("search_kernel/graph_edges", g.e() as f64);

    // sharded search: wall-clock speedup + cost gap vs shard count
    // (the partition subsystem's headline tradeoff; the `1` row is the
    // single-threaded whole-graph baseline).
    let (single, _) = hag_search(&g, &sc);
    let base = b.run("search_sharded/1", || {
        std::hint::black_box(hag_search(&g, &sc));
    });
    json.push(&base);
    for &k in &[2usize, 4, 8] {
        let (hag, stats) = search_sharded(&g, k, &sc);
        let run = b.run(&format!("search_sharded/{k}"), || {
            std::hint::black_box(search_sharded(&g, k, &sc));
        });
        let speedup = base.median.as_secs_f64()
            / run.median.as_secs_f64().max(1e-12);
        let gap = 100.0 * (hag.cost_core() as f64
            / single.cost_core().max(1) as f64 - 1.0);
        println!(
            "  -> {k} shards ({} threads): cost {} vs {} \
             ({gap:+.2}% gap), cut {:.1}%, speedup {speedup:.2}x",
            stats.threads, hag.cost_core(), single.cost_core(),
            100.0 * stats.report.cut_frac);
        json.push(&run);
        json.derived_num(&format!("search_sharded/{k}/speedup"),
                         speedup);
        json.derived_num(&format!("search_sharded/{k}/cost_gap_pct"),
                         gap);
        json.derived_num(&format!("search_sharded/{k}/cut_pct"),
                         100.0 * stats.report.cut_frac);
    }

    // session plan cache: one delta dirties one shard; plan()
    // re-searches only that shard and splices the other three from
    // the cache. Compare against lowering a cold session each time.
    let spec = LowerSpec::default().with_shards(4);
    let mut session = Session::from_graph(&g, spec.clone());
    session.plan(); // warm the cache
    // toggle one intra-shard edge: bounded graph churn, exactly one
    // dirty shard per iteration
    let (mut eu, mut ev) = (0u32, 0u32);
    'find: for (v, ns) in g.iter() {
        for &u in ns {
            if session.shard_of(u) == session.shard_of(v) {
                eu = u;
                ev = v;
                break 'find;
            }
        }
    }
    let cold = b.run("session_plan/cold", || {
        std::hint::black_box(
            Session::from_graph(&g, spec.clone()).plan());
    });
    let mut present = true;
    let warm = b.run("session_plan/dirty_1_of_4", || {
        let d = if present {
            GraphDelta::EdgeDelete { src: eu, dst: ev }
        } else {
            GraphDelta::EdgeInsert { src: eu, dst: ev }
        };
        present = !present;
        assert!(session.apply(d));
        std::hint::black_box(session.plan());
    });
    let st = session.stats();
    let replan_speedup = cold.median.as_secs_f64()
        / warm.median.as_secs_f64().max(1e-12);
    println!(
        "  -> dirty-shard re-plan: {replan_speedup:.2}x faster than \
         cold lowering ({} shard re-searches, {} cache hits across \
         {} plans)",
        st.shard_searches, st.shard_cache_hits, st.plans);
    json.push(&cold);
    json.push(&warm);
    json.derived_num("session_plan/replan_speedup_vs_cold",
                     replan_speedup);

    let out = std::env::var("BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_search.json".to_string());
    json.write(Path::new(&out))
        .unwrap_or_else(|e| panic!("writing {out}: {e}"));
    println!("wrote {out}");
}
