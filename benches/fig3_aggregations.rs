//! Fig 3 bench: HAG search on every dataset, measuring search
//! throughput and printing the aggregation/data-transfer reductions
//! (both set and sequential AGGREGATE). Structure-only: no artifacts
//! needed. Run: `cargo bench --bench fig3_aggregations`.

use repro::bench::effective_scale;
use repro::datasets;
use repro::hag::{hag_search, AggregateKind};
use repro::session::LowerSpec;
use repro::util::benchkit::Bencher;

fn main() {
    let base = 0.02; // small enough for repeated iterations
    let b = Bencher::quick();
    for kind in [AggregateKind::Set, AggregateKind::Sequential] {
        for name in datasets::names() {
            let ds =
                datasets::load(name, effective_scale(name, base), 7);
            // knob derivation through the canonical spec, so the
            // bench measures exactly what `repro search` lowers
            let cfg = LowerSpec::default().with_kind(kind)
                .search_config(ds.graph.n());
            let (_, stats) = hag_search(&ds.graph, &cfg);
            println!(
                "[fig3 {kind:?} {name}] aggs {} -> {} ({:.2}x), tx {} \
                 -> {} ({:.2}x)",
                stats.aggregations_before, stats.aggregations_after,
                stats.aggregations_before as f64
                    / stats.aggregations_after.max(1) as f64,
                stats.transfers_before, stats.transfers_after,
                stats.transfers_before as f64
                    / stats.transfers_after.max(1) as f64);
            b.run(&format!("fig3_search/{kind:?}/{name}"), || {
                std::hint::black_box(hag_search(&ds.graph, &cfg));
            });
        }
    }
}
