//! Streaming-maintenance bench (`rust/src/incremental/`): per-update
//! repair latency vs a full Algorithm-3 re-search, and the cost gap
//! the repaired HAG carries after a long random update stream, swept
//! over drift thresholds (the policy's rebuild-rate/quality tradeoff).
//!
//! Run: `cargo bench --bench stream_updates`
//! CI smoke (bounded sizes): `cargo bench --bench stream_updates -- --smoke`
//!
//! The final section drives the same serving stack through the TCP
//! front end (`rust/src/net/`) over loopback and emits
//! `BENCH_serve.json` (benchkit-v1; path override: `BENCH_SERVE_JSON`)
//! with client-observed wire latencies.

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use repro::coordinator::{self, BatchPolicy, SwapPolicy};
use repro::datasets::{community_graph, CommunityCfg};
use repro::hag::hag_search;
use repro::incremental::{random_delta, DriftPolicy, GraphDelta,
                         StreamConfig, StreamEngine};
use repro::net::{Client, NetConfig, NetServer, Outcome};
use repro::obs::metrics::MetricsRegistry;
use repro::session::{LowerSpec, Session};
use repro::util::benchkit::{BenchJson, Bencher};
use repro::util::Rng;

fn community(n: usize, e: usize, seed: u64) -> repro::graph::Graph {
    let cfg = CommunityCfg {
        n,
        e,
        communities: (n / 160).max(4),
        intra_frac: 0.9,
        zipf_exp: 0.9,
        clone_frac: 0.5,
    };
    community_graph(&cfg, seed).0
}

/// Drive `updates` random deltas through an engine, returning sorted
/// per-apply latencies (us) and the engine.
fn drive(g: &repro::graph::Graph, cfg: StreamConfig, updates: usize,
         seed: u64) -> (Vec<f64>, StreamEngine) {
    let mut eng = StreamEngine::new(g, cfg);
    let mut rng = Rng::seed_from_u64(seed);
    let mut lat = Vec::with_capacity(updates);
    for _ in 0..updates {
        let d = random_delta(&mut rng, eng.overlay(), 0.5, 0.01);
        let t = std::time::Instant::now();
        eng.apply(d);
        lat.push(t.elapsed().as_secs_f64() * 1e6);
    }
    eng.finish_rebuild();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (lat, eng)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let b = Bencher::quick();
    let sizes: &[(usize, usize, usize)] = if smoke {
        &[(1_000, 20_000, 2_000)]
    } else {
        &[(4_000, 80_000, 10_000), (16_000, 320_000, 10_000)]
    };

    // repair latency vs full re-search
    for &(n, e, updates) in sizes {
        let g = community(n, e, 19);
        let (lat, eng) = drive(&g, StreamConfig::default(), updates, 19);
        let g_now = eng.graph();
        let sc = eng.search_config();
        let full = b.run(&format!("stream_updates/full_search/n{n}"),
                         || {
                             std::hint::black_box(
                                 hag_search(&g_now, &sc));
                         });
        let (fresh, _) = hag_search(&g_now, &sc);
        let full_us = full.median.as_secs_f64() * 1e6;
        let p50 = lat[lat.len() / 2];
        let p99 = lat[(lat.len() as f64 * 0.99) as usize - 1];
        let s = eng.stats();
        println!(
            "  -> n{n}: {updates} updates; repair p50 {p50:.1} us \
             p99 {p99:.1} us; full re-search {:.1} ms = {:.0}x \
             median repair; {} fallbacks, {} re-merges, {} rebuilds",
            full_us / 1e3, full_us / p50.max(1e-9), s.fallbacks,
            s.remerge_merges, s.rebuild_swaps);
        println!(
            "  -> n{n}: cost maintained {} vs fresh {} ({:+.2}% gap)",
            eng.cost_core(), fresh.cost_core(),
            100.0 * (eng.cost_core() as f64
                / fresh.cost_core().max(1) as f64 - 1.0));
    }

    // cost-gap-after-stream sweep over drift thresholds (rebuild rate
    // vs quality; INFINITY = repair + re-merge only, never re-search)
    let (n, e, updates) = if smoke {
        (1_000usize, 20_000usize, 2_000usize)
    } else {
        (8_000, 160_000, 10_000)
    };
    let g = community(n, e, 23);
    println!("\ndrift-threshold sweep (n{n}, {updates} updates):");
    for &thr in &[0.02f64, 0.05, 0.10, f64::INFINITY] {
        let mut cfg = StreamConfig::default();
        cfg.policy.threshold = thr;
        let t0 = std::time::Instant::now();
        let (_, eng) = drive(&g, cfg, updates, 23);
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        let g_now = eng.graph();
        let (fresh, _) = hag_search(&g_now, &eng.search_config());
        println!(
            "  -> threshold {thr:>8.2}: cost {} vs fresh {} \
             ({:+.2}% gap), {} rebuilds, {:.0} ms total",
            eng.cost_core(), fresh.cost_core(),
            100.0 * (eng.cost_core() as f64
                / fresh.cost_core().max(1) as f64 - 1.0),
            eng.stats().rebuild_swaps, wall_ms);
    }

    // session plan cache over a live stream: the engine repairs per
    // delta, the session re-plans only dirty shards on a cadence and
    // the engine adopts the spliced result (the ROADMAP-1 path that
    // replaces whole-graph rebuilds). The cached re-plan must stay
    // identical to the from-scratch comparator.
    let plan_every = if smoke { 250 } else { 500 };
    println!("\nsession plan cache (n{n}, 4 shards, {updates} updates, \
              re-plan every {plan_every}):");
    let g = community(n, e, 29);
    let spec = LowerSpec::default().with_shards(4);
    let mut session = Session::from_graph(&g, spec.clone());
    let mut ecfg = spec.stream_config();
    ecfg.policy.threshold = f64::INFINITY; // session owns re-planning
    let mut eng = StreamEngine::new(&g, ecfg);
    let mut rng = Rng::seed_from_u64(29);
    let mut replan_ms: Vec<f64> = Vec::new();
    for i in 0..updates {
        let d = random_delta(&mut rng, eng.overlay(), 0.5, 0.01);
        eng.apply(d);
        session.apply(d);
        if (i + 1) % plan_every == 0 {
            let t = std::time::Instant::now();
            let (hag, _plan) = session.plan();
            replan_ms.push(t.elapsed().as_secs_f64() * 1e3);
            assert!(eng.install_hag(&hag));
        }
    }
    let (hag_c, plan_c) = session.plan();
    let (hag_f, plan_f) = session.plan_fresh();
    assert!(*hag_c == hag_f && *plan_c == plan_f,
            "cached dirty-shard re-plan != from-scratch build_plan");
    replan_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let st = session.stats();
    println!(
        "  -> {} plans; {} shard re-searches vs {updates} updates; \
         {} shard cache hits; median dirty re-plan {:.1} ms; \
         cached == from-scratch OK",
        st.plans, st.shard_searches, st.shard_cache_hits,
        replan_ms[replan_ms.len() / 2]);

    // session-aware serving: a resident session rides in the batcher,
    // a shard-0-localized update stream is coalesced between scoring
    // batches, and drift (forced: negative threshold) hot-swaps the
    // spliced dirty-shard re-plan into the live worker. Runs on the
    // host reference executor when PJRT artifacts are absent, so the
    // CI smoke covers the full serving path.
    let (reqs, upd_every) = if smoke { (200usize, 4usize) } else {
        (1_000, 4)
    };
    println!("\nsession-aware serving (BZR stand-in, 4 shards, \
              {reqs} requests, localized updates):");
    let ds = repro::datasets::load("BZR", 0.02, 31);
    let spec = LowerSpec::default()
        .with_shards(4)
        .with_drift(DriftPolicy::default().with_threshold(-1.0));
    let mut session = Session::new(&ds, spec);
    let lowered = session.lower().expect("lower");
    let members: Vec<u32> = (0..ds.n() as u32)
        .filter(|&v| session.shard_of(v) == 0)
        .collect();
    let resident = coordinator::Resident::new(
        session, &ds.graph, &lowered.hag,
        SwapPolicy { swap_plans: true, max_pending: 16 });
    let server = coordinator::InferenceServer::for_lowered(
        "artifacts", "gcn", &ds, &lowered, BatchPolicy::default(), 31,
        Some(resident)).expect("spawn");
    let tx = server.client();
    let mut rng = Rng::seed_from_u64(31);
    for i in 0..reqs {
        if i % upd_every == 0 && members.len() >= 2 {
            let a = members[rng.range_usize(0, members.len())];
            let b = members[rng.range_usize(0, members.len())];
            if a != b {
                let _ = tx.send(coordinator::ServerMsg::Update(
                    coordinator::UpdateRequest {
                        delta: GraphDelta::EdgeInsert { src: a, dst: b },
                        reply: None,
                        submitted: Instant::now(),
                    }));
            }
        }
        let (otx, orx) = coordinator::server::oneshot();
        let req = coordinator::ScoreRequest {
            node: rng.range_u32(0, ds.n() as u32),
            features: (0..ds.f_in)
                .map(|_| rng.range_f32(-1.0, 1.0)).collect(),
            reply: otx,
            submitted: Instant::now(),
            pin_epoch: None,
        };
        if tx.send(coordinator::ServerMsg::Score(req)).is_err() {
            break;
        }
        let _ = orx.recv().expect("reply").into_result()
            .expect("scored");
    }
    drop(tx);
    let out = server.shutdown_outcome();
    let s = &out.stats;
    assert_ne!(s.plan_matches_fresh, Some(false),
               "serving-path plan cache contract violated");
    println!(
        "  -> {} ok / {} rejected; p50 {:.2} ms p99 {:.2} ms; \
         {} updates in {} flushes; {} plan swaps ({} skipped); \
         {} shard re-searches, {} shard cache hits; replan check {:?}",
        s.requests, s.rejected, s.p50_ms, s.p99_ms, s.updates,
        s.update_batches, s.plan_swaps, s.swaps_skipped,
        s.shard_searches, s.shard_cache_hits, s.plan_matches_fresh);

    // wire-level serving: the same stack behind the TCP front end,
    // scored over loopback through the length-prefixed protocol.
    // Client-observed latency = framing + socket + batcher + exec.
    let wire_reqs = if smoke { 200usize } else { 2_000 };
    println!("\nserve wire (BZR stand-in, {wire_reqs} loopback \
              round-trips):");
    let ds = repro::datasets::load("BZR", 0.02, 37);
    let mut session = Session::new(&ds,
                                   LowerSpec::default().with_shards(2));
    let lowered = session.lower().expect("lower");
    let server = coordinator::InferenceServer::for_lowered(
        "artifacts", "gcn", &ds, &lowered, BatchPolicy::default(), 37,
        None).expect("spawn");
    let net = NetServer::spawn("127.0.0.1:0", server.client(),
                               server.epoch_cell(),
                               Arc::new(MetricsRegistry::new()),
                               NetConfig::default())
        .expect("bind loopback");
    let mut c = Client::connect(net.local_addr()).expect("connect");
    let epoch_before = c.ping().expect("ping");
    let mut rng = Rng::seed_from_u64(37);
    let mut wire_us: Vec<f64> = Vec::with_capacity(wire_reqs);
    let mut ok = 0usize;
    for _ in 0..wire_reqs {
        let node = rng.range_u32(0, ds.n() as u32);
        let feats: Vec<f32> = (0..ds.f_in)
            .map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let t = Instant::now();
        match c.score(node, &feats).expect("wire round-trip") {
            Outcome::Ok(_) => ok += 1,
            Outcome::Rejected(r) => panic!("unexpected shed: {r}"),
        }
        wire_us.push(t.elapsed().as_secs_f64() * 1e6);
    }
    let epoch_after = c.ping().expect("ping");
    assert!(epoch_after >= epoch_before, "epochs went backwards");
    drop(c);
    let net_stats = net.drain(Duration::from_secs(5));
    let _ = server.shutdown();

    wire_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = wire_us[wire_us.len() / 2];
    let p99 = wire_us[((wire_us.len() as f64 * 0.99) as usize)
                      .min(wire_us.len() - 1)];
    let mean = wire_us.iter().sum::<f64>() / wire_us.len() as f64;
    println!(
        "  -> {ok}/{wire_reqs} ok over the wire; client p50 \
         {p50:.1} us p99 {p99:.1} us; {} accepted, {} shed, \
         {} protocol errors",
        net_stats.accepted, net_stats.shed,
        net_stats.protocol_errors);

    let mut json = BenchJson::new();
    json.push_entry("serve_wire/score_roundtrip", wire_us.len() as u64,
                    p50 / 1e6, mean / 1e6,
                    wire_us[0] / 1e6,
                    wire_us[wire_us.len() - 1] / 1e6);
    json.derived_num("serve.requests", ok as f64);
    json.derived_num("serve.wire_p50_us", p50);
    json.derived_num("serve.wire_p99_us", p99);
    json.derived_num("serve.accepted", net_stats.accepted as f64);
    json.derived_num("serve.shed", net_stats.shed as f64);
    json.derived_num("serve.drained", net_stats.drained as f64);
    json.derived_num("serve.protocol_errors",
                     net_stats.protocol_errors as f64);
    json.derived_num("serve.epoch", epoch_after as f64);
    let out = std::env::var("BENCH_SERVE_JSON")
        .unwrap_or_else(|_| "BENCH_serve.json".to_string());
    json.write(Path::new(&out))
        .unwrap_or_else(|e| panic!("writing {out}: {e}"));
    println!("wrote {out}");
}
