"""Differentiable wrappers around the L1 Pallas kernels.

This is the paper's §5.1 operator pair:

* ``hag_aggregate``   — forward aggregation over an execution plan
  (HAG levels + final block-CSR segment-sum), built from the Pallas
  kernels;
* ``hag_aggregate_grad`` — its backward pass, registered via
  ``jax.custom_vjp`` so ``jax.grad`` flows through the whole 2-layer model
  inside one AOT-compiled train step.

``pallas_call`` has no automatic VJP, so each kernel gets an explicit
custom_vjp. Backward passes are the exact transposes:

* ``level_combine`` bwd: scatter-add of the cotangent into both operand
  slots (XLA ``scatter`` — fused by the CPU/TPU backends);
* ``block_spmm``  bwd: the transpose gather/scatter — for every nnz slot
  ``(b, j)``: ``d_values[blk_col[b,j]] += d_out[b*BR + blk_row[b,j]]``;
* ``tiled_matmul`` bwd: two more ``tiled_matmul`` calls (dx, dw), so the
  backward matmuls also run on the MXU-tiled kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernels


# ----------------------------------------------------------------- matmul

@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def matmul(x, w, bm=128, bn=128, bk=128):
    return kernels.tiled_matmul(x, w, bm=bm, bn=bn, bk=bk)


def _matmul_fwd(x, w, bm, bn, bk):
    return matmul(x, w, bm, bn, bk), (x, w)


def _matmul_bwd(bm, bn, bk, res, g):
    x, w = res
    # dx = g @ w.T ; dw = x.T @ g — both on the Pallas kernel.
    dx = kernels.tiled_matmul(g, w.T, bm=bm, bn=bn, bk=bk)
    dw = kernels.tiled_matmul(x.T, g, bm=bm, bn=bn, bk=bk)
    return dx, dw


matmul.defvjp(_matmul_fwd, _matmul_bwd)


# ---------------------------------------------------------- level_combine

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def level_combine(values, left, right, block_len=128):
    return kernels.level_combine(values, left, right, block_len=block_len)


def _level_combine_fwd(values, left, right, block_len):
    out = level_combine(values, left, right, block_len)
    # residuals must be jax values; `values` is saved only to supply the
    # cotangent's shape (XLA keeps no extra copy: zeros_like is shape-only)
    return out, (values, left, right)


def _level_combine_bwd(block_len, res, g):
    values, left, right = res
    dv = jnp.zeros_like(values)
    dv = dv.at[left].add(g).at[right].add(g)
    # The pinned zero slot must stay zero-gradient: padding entries point
    # at it, but its cotangent is irrelevant because the primal is never
    # read as a trainable value; we still zero it for plan hygiene.
    dv = dv.at[values.shape[0] - 1].set(0.0)
    return dv, None, None


level_combine.defvjp(_level_combine_fwd, _level_combine_bwd)


# -------------------------------------------------------------- block_spmm

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def block_spmm(values, blk_col, blk_row, block_rows):
    return kernels.block_spmm(values, blk_col, blk_row, block_rows)


def _block_spmm_fwd(values, blk_col, blk_row, block_rows):
    out = block_spmm(values, blk_col, blk_row, block_rows)
    return out, (values, blk_col, blk_row)


def _block_spmm_bwd(block_rows, res, g):
    values, blk_col, blk_row = res
    nb, nnzb = blk_col.shape
    # global output row per nnz slot: b * BR + blk_row[b, j]
    grow = (jnp.arange(nb, dtype=blk_row.dtype)[:, None] * block_rows
            + blk_row)                                     # [NB, NNZB]
    gslot = g[grow.reshape(-1)]                            # [NB*NNZB, F]
    dv = jnp.zeros_like(values)
    dv = dv.at[blk_col.reshape(-1)].add(gslot)
    dv = dv.at[values.shape[0] - 1].set(0.0)
    return dv, None, None


block_spmm.defvjp(_block_spmm_fwd, _block_spmm_bwd)


# ---------------------------------------------------- max variants (fwd +
# argmax-routed bwd; operands must be >= 0, see kernels.csr_spmm)

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def block_spmm_max(values, blk_col, blk_row, block_rows):
    return kernels.block_spmm_max(values, blk_col, blk_row, block_rows)


def _block_spmm_max_fwd(values, blk_col, blk_row, block_rows):
    out = block_spmm_max(values, blk_col, blk_row, block_rows)
    return out, (values, blk_col, blk_row, out)


def _block_spmm_max_bwd(block_rows, res, g):
    values, blk_col, blk_row, out = res
    nb, nnzb = blk_col.shape
    grow = (jnp.arange(nb, dtype=blk_row.dtype)[:, None] * block_rows
            + blk_row).reshape(-1)                         # [NB*NNZB]
    cols = blk_col.reshape(-1)
    # Route the cotangent to slots that achieved the max (ties split the
    # gradient across all achievers, matching jnp.max's subgradient
    # convention closely enough for training).
    achieved = (values[cols] == out[grow]).astype(values.dtype)
    dv = jnp.zeros(values.shape, dtype=values.dtype)
    dv = dv.at[cols].add(achieved * g[grow])
    dv = dv.at[values.shape[0] - 1].set(0.0)
    return dv, None, None


block_spmm_max.defvjp(_block_spmm_max_fwd, _block_spmm_max_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def level_combine_max(values, left, right, block_len=128):
    return kernels.level_combine_max(values, left, right,
                                     block_len=block_len)


def _level_combine_max_fwd(values, left, right, block_len):
    out = level_combine_max(values, left, right, block_len)
    return out, (values, left, right, out)


def _level_combine_max_bwd(block_len, res, g):
    values, left, right, out = res
    dl = (values[left] == out).astype(values.dtype) * g
    dr = (values[right] == out).astype(values.dtype) * g
    dv = jnp.zeros(values.shape, dtype=values.dtype)
    dv = dv.at[left].add(dl).at[right].add(dr)
    dv = dv.at[values.shape[0] - 1].set(0.0)
    return dv, None, None


level_combine_max.defvjp(_level_combine_max_fwd, _level_combine_max_bwd)
