"""L2 — the GNN compute graph (JAX, build-time only).

Implements the paper's Algorithm 2 over the plan-tensor encoding from
``buckets.py``: 2-layer GCN (Table 1 row 1) and GraphSAGE-P (row 2), node-
and graph-classification heads, full training step (loss + ``jax.grad`` +
Adam) — all lowered by ``aot.py`` into single HLO programs that the rust
coordinator executes without any Python.

The hierarchical aggregation for *sum* aggregates is linear in the input
activations, so its VJP is implemented as the exact transpose-plan
execution (the paper's ``hag_aggregate_grad``) with **zero saved
activations** — this is the paper's §3.2 observation that the ``a-hat``
buffers need not be memorized for backprop. The max variant (GraphSAGE-P)
is nonlinear and uses the per-kernel custom VJPs from ``ops.py`` instead.
"""

from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from . import ops
from .buckets import Bucket


# =====================================================================
# Hierarchical aggregation (Algorithm 2, lines 4-8)
# =====================================================================

def _levels_forward(buf, lvl_left, lvl_right, bucket: Bucket, combine):
    """Evaluate aggregation-node levels in topological order.

    Level l writes its l_pad results into buffer slots
    [n_pad + l*l_pad, n_pad + (l+1)*l_pad) — contiguous by construction
    (the rust scheduler allocates slots level-major), so the scatter is a
    dense dynamic_update_slice.
    """
    if bucket.levels == 0:
        return buf
    # Static unroll (levels is small, <= ~8): lets XLA fuse each level's
    # gather+add+update and use static-offset slice updates, which the
    # scan + dynamic_update_slice form prevented (perf pass, §Perf).
    for l in range(bucket.levels):
        out = combine(buf, lvl_left[l], lvl_right[l], bucket.lvl_block)
        buf = jax.lax.dynamic_update_slice(
            buf, out, (bucket.n_pad + l * bucket.l_pad, 0))
    return buf


def _bands_forward(buf, band_cols, band_rows, bucket: Bucket, spmm):
    """Final per-node aggregation (Algorithm 2, line 8): one block-CSR
    segment-sum per degree band, concatenated to [n_pad, F]."""
    parts = [spmm(buf, bc, br_, bucket.br)
             for bc, br_ in zip(band_cols, band_rows)]
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)


def _bands_scatter_sum(buf, band_cols, band_rows, bucket: Bucket):
    """Scatter-add band aggregation (bucket.impl == "scatter"): XLA
    scatter with work ~ E*F — the CPU-optimal path (the Pallas one-hot
    matmul inflates FLOPs by BR, free on the MXU, 12.6x slower on CPU;
    EXPERIMENTS.md §Perf). Semantics identical to _bands_forward(sum)."""
    out = jnp.zeros((bucket.n_pad, buf.shape[1]), buf.dtype)
    row0 = 0
    for bc, brw in zip(band_cols, band_rows):
        nb, nnzb = bc.shape
        grow = (row0
                + jnp.arange(nb, dtype=brw.dtype)[:, None] * bucket.br
                + brw)
        out = out.at[grow.reshape(-1)].add(buf[bc.reshape(-1)])
        row0 += nb * bucket.br
    return out


def _hag_aggregate_sum_impl(h, lvl_left, lvl_right, band_cols, band_rows,
                            bucket: Bucket):
    f = h.shape[1]
    buf = jnp.zeros((bucket.m_pad, f), h.dtype)
    buf = jax.lax.dynamic_update_slice(buf, h, (0, 0))
    buf = _levels_forward(buf, lvl_left, lvl_right, bucket,
                          ops.level_combine)
    if bucket.impl == "scatter":
        return _bands_scatter_sum(buf, band_cols, band_rows, bucket)
    return _bands_forward(buf, band_cols, band_rows, bucket, ops.block_spmm)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def hag_aggregate_sum(h, lvl_left, lvl_right, band_cols, band_rows,
                      bucket: Bucket):
    """Sum-aggregate over a HAG plan. h: [n_pad, F] -> agg: [n_pad, F].

    band_cols/band_rows are tuples (one [nb, nnzb] i32 tensor per band).
    """
    return _hag_aggregate_sum_impl(h, lvl_left, lvl_right, band_cols,
                                   band_rows, bucket)


def _hag_sum_fwd(h, lvl_left, lvl_right, band_cols, band_rows, bucket):
    out = _hag_aggregate_sum_impl(h, lvl_left, lvl_right, band_cols,
                                  band_rows, bucket)
    # Linear op: only the plan (indices) is needed for the backward pass.
    return out, (lvl_left, lvl_right, band_cols, band_rows, h.shape[1])


def _hag_sum_bwd(bucket: Bucket, res, g):
    """The paper's hag_aggregate_grad: execute the transpose plan.

    d_buf accumulates cotangents for every buffer slot; bands scatter the
    output cotangent into their source slots, then levels propagate in
    reverse topological order (each level's cotangent flows to both of
    its operand slots). No forward activations are consumed — the sum
    aggregation is linear (paper §3.2: a-hat is never memorized).
    """
    lvl_left, lvl_right, band_cols, band_rows, f = res
    dtype = g.dtype
    dbuf = jnp.zeros((bucket.m_pad, f), dtype)

    # --- transpose of the band segment-sums
    row0 = 0
    for bc, brw in zip(band_cols, band_rows):
        nb, nnzb = bc.shape
        grow = (row0 + jnp.arange(nb, dtype=brw.dtype)[:, None] * bucket.br
                + brw).reshape(-1)
        dbuf = dbuf.at[bc.reshape(-1)].add(g[grow])
        row0 += nb * bucket.br

    # --- transpose of the levels, reverse topological order (static
    # unroll, mirroring _levels_forward)
    for l in reversed(range(bucket.levels)):
        off = bucket.n_pad + l * bucket.l_pad
        gl = jax.lax.dynamic_slice(dbuf, (off, 0), (bucket.l_pad, f))
        dbuf = dbuf.at[lvl_left[l]].add(gl).at[lvl_right[l]].add(gl)

    dh = jax.lax.dynamic_slice(dbuf, (0, 0), (bucket.n_pad, f))
    return dh, None, None, None, None


hag_aggregate_sum.defvjp(_hag_sum_fwd, _hag_sum_bwd)


def hag_aggregate_max(h, lvl_left, lvl_right, band_cols, band_rows,
                      bucket: Bucket):
    """Max-aggregate (GraphSAGE-P). Nonlinear: AD goes through the
    per-kernel custom VJPs (scan carries are saved — the memory-free
    transpose trick only applies to linear aggregates)."""
    f = h.shape[1]
    buf = jnp.zeros((bucket.m_pad, f), h.dtype)
    buf = jax.lax.dynamic_update_slice(buf, h, (0, 0))
    buf = _levels_forward(buf, lvl_left, lvl_right, bucket,
                          ops.level_combine_max)
    return _bands_forward(buf, band_cols, band_rows, bucket,
                          ops.block_spmm_max)


# =====================================================================
# Models (Table 1)
# =====================================================================

def init_gcn_params(bucket: Bucket, seed: int = 0) -> Dict[str, jnp.ndarray]:
    """Glorot-ish init for the 2-layer GCN."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    s1 = (2.0 / (bucket.f_in + bucket.hidden)) ** 0.5
    s2 = (2.0 / (bucket.hidden + bucket.classes)) ** 0.5
    return {
        "w1": jax.random.normal(k1, (bucket.f_in, bucket.hidden)) * s1,
        "b1": jnp.zeros((bucket.hidden,)),
        "w2": jax.random.normal(k2, (bucket.hidden, bucket.classes)) * s2,
        "b2": jnp.zeros((bucket.classes,)),
    }


PARAM_ORDER = ("w1", "b1", "w2", "b2")


def gcn_forward(params, h0, deg, plan, bucket: Bucket):
    """2-layer GCN (Table 1): h' = relu(W . (a_v + h_v)/(|N(v)|+1)).

    plan = (lvl_left, lvl_right, band_cols, band_rows); both layers reuse
    the same plan (Algorithm 2 runs the same HAG every layer).
    Returns final-layer logits [n_pad, classes].
    """
    lvl_l, lvl_r, bcs, brs = plan
    norm = 1.0 / (deg + 1.0)

    a1 = hag_aggregate_sum(h0, lvl_l, lvl_r, bcs, brs, bucket)
    z1 = (a1 + h0) * norm[:, None]
    h1 = jax.nn.relu(ops.matmul(z1, params["w1"]) + params["b1"])

    a2 = hag_aggregate_sum(h1, lvl_l, lvl_r, bcs, brs, bucket)
    z2 = (a2 + h1) * norm[:, None]
    return ops.matmul(z2, params["w2"]) + params["b2"]


def init_sage_params(bucket: Bucket, seed: int = 0) -> Dict[str, jnp.ndarray]:
    """GraphSAGE-P: per-layer pool transform + update over concat."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)

    def glorot(k, i, o):
        return jax.random.normal(k, (i, o)) * (2.0 / (i + o)) ** 0.5

    f, h, c = bucket.f_in, bucket.hidden, bucket.classes
    return {
        "wp1": glorot(ks[0], f, h), "bp1": jnp.zeros((h,)),
        "wu1": glorot(ks[1], h + f, h), "bu1": jnp.zeros((h,)),
        "wp2": glorot(ks[2], h, h), "bp2": jnp.zeros((h,)),
        "wu2": glorot(ks[3], h + h, c), "bu2": jnp.zeros((c,)),
    }


SAGE_PARAM_ORDER = ("wp1", "bp1", "wu1", "bu1", "wp2", "bp2", "wu2", "bu2")


def sage_forward(params, h0, deg, plan, bucket: Bucket):
    """GraphSAGE-P (Table 1): a_v = max_u relu(W1 . h_u);
    h_v' = relu(W2 . (a_v, h_v)). Max-pool aggregation over the HAG."""
    del deg  # SAGE-P does not degree-normalize
    lvl_l, lvl_r, bcs, brs = plan

    z1 = jax.nn.relu(ops.matmul(h0, params["wp1"]) + params["bp1"])
    a1 = hag_aggregate_max(z1, lvl_l, lvl_r, bcs, brs, bucket)
    h1 = jax.nn.relu(
        ops.matmul(jnp.concatenate([a1, h0], axis=1), params["wu1"])
        + params["bu1"])

    z2 = jax.nn.relu(ops.matmul(h1, params["wp2"]) + params["bp2"])
    a2 = hag_aggregate_max(z2, lvl_l, lvl_r, bcs, brs, bucket)
    return (ops.matmul(jnp.concatenate([a2, h1], axis=1), params["wu2"])
            + params["bu2"])


# =====================================================================
# Heads + losses
# =====================================================================

def masked_softmax_ce(logits, labels, mask):
    """Mean CE over mask-selected rows; padding rows contribute 0."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return -jnp.sum(picked * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def graph_pool(h, graph_seg, graph_sizes, g_pad: int):
    """Mean-pool node activations per graph (graph classification head).

    graph_seg: [n_pad] graph id per node (padding -> g_pad-1, the sink);
    graph_sizes: [g_pad] true node counts (sink size irrelevant, >= 1).
    """
    pooled = jnp.zeros((g_pad, h.shape[1]), h.dtype).at[graph_seg].add(h)
    return pooled / jnp.maximum(graph_sizes, 1.0)[:, None]


def accuracy(logits, labels, mask):
    pred = jnp.argmax(logits, axis=-1)
    hits = (pred == labels).astype(jnp.float32) * mask
    return jnp.sum(hits) / jnp.maximum(jnp.sum(mask), 1.0)


# =====================================================================
# Training step (Adam inside the artifact)
# =====================================================================

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


def init_opt_state(params):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in params.items()},
            "step": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, opt, lr: float):
    step = opt["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - ADAM_B1 ** t
    bc2 = 1.0 - ADAM_B2 ** t
    new_m, new_v, new_p = {}, {}, {}
    for k in params:
        m = ADAM_B1 * opt["m"][k] + (1 - ADAM_B1) * grads[k]
        v = ADAM_B2 * opt["v"][k] + (1 - ADAM_B2) * grads[k] ** 2
        new_m[k], new_v[k] = m, v
        new_p[k] = params[k] - lr * (m / bc1) / (jnp.sqrt(v / bc2)
                                                 + ADAM_EPS)
    return new_p, {"m": new_m, "v": new_v, "step": step}


def make_node_train_step(bucket: Bucket, forward, lr: float = 0.01):
    """Node-classification train step: returns a function over flat plan
    tensors suitable for AOT lowering. Loss is masked softmax CE."""

    def train_step(params, opt, h0, deg, labels, mask,
                   lvl_left, lvl_right, band_cols, band_rows):
        plan = (lvl_left, lvl_right, band_cols, band_rows)

        def loss_fn(p):
            logits = forward(p, h0, deg, plan, bucket)
            return masked_softmax_ce(logits, labels, mask), logits

        (loss, logits), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        new_p, new_opt = adam_update(params, grads, opt, lr)
        return new_p, new_opt, loss, accuracy(logits, labels, mask)

    return train_step


def make_graph_train_step(bucket: Bucket, forward, lr: float = 0.01):
    """Graph-classification train step (mean-pool head, paper §5.2)."""

    def train_step(params, opt, h0, deg, graph_seg, graph_sizes,
                   graph_labels, graph_mask,
                   lvl_left, lvl_right, band_cols, band_rows):
        plan = (lvl_left, lvl_right, band_cols, band_rows)

        def loss_fn(p):
            logits = forward(p, h0, deg, plan, bucket)
            glogits = graph_pool(logits, graph_seg, graph_sizes,
                                 bucket.g_pad)
            return masked_softmax_ce(glogits, graph_labels,
                                     graph_mask), glogits

        (loss, glogits), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        new_p, new_opt = adam_update(params, grads, opt, lr)
        return (new_p, new_opt, loss,
                accuracy(glogits, graph_labels, graph_mask))

    return train_step


def make_inference(bucket: Bucket, forward):
    """Inference entry: logits only (serving path)."""

    def inference(params, h0, deg, lvl_left, lvl_right, band_cols,
                  band_rows):
        plan = (lvl_left, lvl_right, band_cols, band_rows)
        return forward(params, h0, deg, plan, bucket)

    return inference
