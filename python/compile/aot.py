"""AOT lowering: JAX model -> HLO text artifacts + manifest.

Emits one HLO **text** program per (model x kind x bucket) — text, not
``.serialize()``: the image's xla_extension 0.5.1 rejects jax>=0.5's
64-bit-instruction-id protos, while the text parser reassigns ids (see
/opt/xla-example/README.md). The rust runtime loads these with
``HloModuleProto::from_text_file`` and compiles them on the PJRT CPU
client once at startup.

``manifest.json`` records, for every artifact, the exact flat input and
output literal layout (name, dtype, shape) so the rust side can pack and
unpack buffers without any knowledge of JAX pytree conventions.

Two-phase build (see Makefile):
1. ``repro emit-buckets`` (rust) writes ``artifacts/buckets.json`` with
   the exact bucket every benchmark workload needs (sizes depend on the
   HAG search result, which lives in rust);
2. ``python -m compile.aot`` compiles the default set plus everything in
   ``buckets.json``.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .buckets import Bucket, load_bucket_specs

F32, I32 = jnp.float32, jnp.int32


def _spec(name: str, shape: Sequence[int], dtype) -> dict:
    return {"name": name, "shape": list(shape),
            "dtype": "f32" if dtype == F32 else "i32"}


def gcn_param_specs(b: Bucket) -> List[dict]:
    return [
        _spec("w1", (b.f_in, b.hidden), F32),
        _spec("b1", (b.hidden,), F32),
        _spec("w2", (b.hidden, b.classes), F32),
        _spec("b2", (b.classes,), F32),
    ]


def sage_param_specs(b: Bucket) -> List[dict]:
    f, h, c = b.f_in, b.hidden, b.classes
    return [
        _spec("wp1", (f, h), F32), _spec("bp1", (h,), F32),
        _spec("wu1", (h + f, h), F32), _spec("bu1", (h,), F32),
        _spec("wp2", (h, h), F32), _spec("bp2", (h,), F32),
        _spec("wu2", (h + h, c), F32), _spec("bu2", (c,), F32),
    ]


PARAM_SPECS = {"gcn": gcn_param_specs, "sage": sage_param_specs}
PARAM_ORDER = {"gcn": M.PARAM_ORDER, "sage": M.SAGE_PARAM_ORDER}
FORWARD = {"gcn": M.gcn_forward, "sage": M.sage_forward}


def plan_specs(b: Bucket) -> List[dict]:
    specs = []
    if b.levels > 0:
        specs.append(_spec("lvl_left", (b.levels, b.l_pad), I32))
        specs.append(_spec("lvl_right", (b.levels, b.l_pad), I32))
    for i, (nb, nnzb) in enumerate(b.bands):
        specs.append(_spec(f"band{i}_col", (nb, nnzb), I32))
        specs.append(_spec(f"band{i}_row", (nb, nnzb), I32))
    return specs


def data_specs(b: Bucket) -> List[dict]:
    specs = [_spec("h0", (b.n_pad, b.f_in), F32),
             _spec("deg", (b.n_pad,), F32)]
    if b.is_graph_cls:
        specs += [
            _spec("graph_seg", (b.n_pad,), I32),
            _spec("graph_sizes", (b.g_pad,), F32),
            _spec("graph_labels", (b.g_pad,), I32),
            _spec("graph_mask", (b.g_pad,), F32),
        ]
    else:
        specs += [_spec("labels", (b.n_pad,), I32),
                  _spec("mask", (b.n_pad,), F32)]
    return specs


def opt_specs(pspecs: List[dict]) -> List[dict]:
    out = [_spec("m_" + s["name"], s["shape"], F32) for s in pspecs]
    out += [_spec("v_" + s["name"], s["shape"], F32) for s in pspecs]
    out.append(_spec("opt_step", (), I32))
    return out


def _unflatten_plan(b: Bucket, flat: List[jnp.ndarray]):
    """Split the flat tail of arguments into (lvl_l, lvl_r, cols, rows)."""
    i = 0
    if b.levels > 0:
        lvl_l, lvl_r = flat[0], flat[1]
        i = 2
    else:
        lvl_l = jnp.zeros((0, 0), I32)
        lvl_r = jnp.zeros((0, 0), I32)
    cols, rows = [], []
    for _ in b.bands:
        cols.append(flat[i]); rows.append(flat[i + 1]); i += 2
    assert i == len(flat)
    return lvl_l, lvl_r, tuple(cols), tuple(rows)


def build_entry(model_name: str, kind: str, b: Bucket, lr: float):
    """Return (fn, input_specs, output_specs) with a fully flat calling
    convention — the manifest contract with the rust runtime."""
    porder = PARAM_ORDER[model_name]
    pspecs = PARAM_SPECS[model_name](b)
    forward = FORWARD[model_name]
    np_ = len(porder)

    if kind == "train":
        ispecs = pspecs + opt_specs(pspecs) + data_specs(b) + plan_specs(b)
        step_fn = (M.make_graph_train_step if b.is_graph_cls
                   else M.make_node_train_step)(b, forward, lr)

        def fn(*flat):
            params = dict(zip(porder, flat[:np_]))
            m = dict(zip(porder, flat[np_:2 * np_]))
            v = dict(zip(porder, flat[2 * np_:3 * np_]))
            opt = {"m": m, "v": v, "step": flat[3 * np_]}
            i = 3 * np_ + 1
            nd = 6 if b.is_graph_cls else 4
            data = flat[i:i + nd]
            plan = _unflatten_plan(b, list(flat[i + nd:]))
            new_p, new_opt, loss, acc = step_fn(params, opt, *data,
                                                plan[0], plan[1],
                                                plan[2], plan[3])
            outs = tuple(new_p[k] for k in porder)
            outs += tuple(new_opt["m"][k] for k in porder)
            outs += tuple(new_opt["v"][k] for k in porder)
            outs += (new_opt["step"], loss, acc)
            return outs

        ospecs = ([_spec("new_" + s["name"], s["shape"], F32)
                   for s in pspecs]
                  + [_spec("new_m_" + s["name"], s["shape"], F32)
                     for s in pspecs]
                  + [_spec("new_v_" + s["name"], s["shape"], F32)
                     for s in pspecs]
                  + [_spec("new_opt_step", (), I32),
                     _spec("loss", (), F32), _spec("acc", (), F32)])
        return fn, ispecs, ospecs

    if kind == "infer":
        dspecs = [_spec("h0", (b.n_pad, b.f_in), F32),
                  _spec("deg", (b.n_pad,), F32)]
        ispecs = pspecs + dspecs + plan_specs(b)
        infer_fn = M.make_inference(b, forward)

        def fn(*flat):
            params = dict(zip(porder, flat[:np_]))
            h0, deg = flat[np_], flat[np_ + 1]
            plan = _unflatten_plan(b, list(flat[np_ + 2:]))
            logits = infer_fn(params, h0, deg, plan[0], plan[1],
                              plan[2], plan[3])
            return (logits,)

        ospecs = [_spec("logits", (b.n_pad, b.classes), F32)]
        return fn, ispecs, ospecs

    raise ValueError(f"unknown kind {kind!r}")


def to_hlo_text(fn, ispecs: List[dict]) -> str:
    shapes = [jax.ShapeDtypeStruct(tuple(s["shape"]),
                                   F32 if s["dtype"] == "f32" else I32)
              for s in ispecs]
    lowered = jax.jit(fn).lower(*shapes)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def default_buckets() -> List[Bucket]:
    """Small always-compiled set: quickstart + integration tests."""
    return [
        # GNN-graph baseline (no levels) and HAG variant, node cls
        Bucket(name="tiny0", n_pad=128, f_in=8, hidden=16, classes=4,
               levels=0, l_pad=0, bands=((16, 16),), br=8),
        Bucket(name="tiny4", n_pad=128, f_in=8, hidden=16, classes=4,
               levels=4, l_pad=128, bands=((16, 16),), br=8),
        # graph-classification variant
        Bucket(name="tinyg", n_pad=128, f_in=8, hidden=16, classes=2,
               levels=2, l_pad=128, bands=((16, 16),), br=8, g_pad=16),
    ]


def compile_all(out_dir: str, buckets: List[Bucket],
                models: Sequence[str] = ("gcn", "sage"),
                lr: float = 0.01, force: bool = False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest_path = os.path.join(out_dir, "manifest.json")
    old = {}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            old = {a["name"]: a for a in json.load(f).get("artifacts", [])}

    artifacts = []
    for b in buckets:
        for mname in models:
            if mname == "sage" and b.is_graph_cls:
                continue  # sage graph-cls not part of the eval matrix
            if mname == "sage" and not b.name.startswith("tiny"):
                # the paper's end-to-end eval (§5.3) trains GCN; SAGE-P
                # is exercised on the default (tiny) buckets only
                continue
            for kind in ("train", "infer"):
                name = f"{mname}_{kind}_{b.name}"
                fname = name + ".hlo.txt"
                fpath = os.path.join(out_dir, fname)
                fn, ispecs, ospecs = build_entry(mname, kind, b, lr)
                key = hashlib.sha256(json.dumps(
                    [b.to_json(), mname, kind, lr]).encode()).hexdigest()
                entry = {
                    "name": name, "file": fname, "model": mname,
                    "kind": kind, "bucket": b.to_json(), "lr": lr,
                    "key": key, "inputs": ispecs, "outputs": ospecs,
                }
                if (not force and name in old and old[name]["key"] == key
                        and os.path.exists(fpath)):
                    artifacts.append(old[name])
                    print(f"  [cached] {name}")
                    continue
                print(f"  [lower ] {name} ...", flush=True)
                text = to_hlo_text(fn, ispecs)
                with open(fpath, "w") as f:
                    f.write(text)
                artifacts.append(entry)
    manifest = {"version": 1, "artifacts": artifacts}
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(artifacts)} artifacts -> {manifest_path}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--buckets", default=None,
                    help="bucket-spec JSON from `repro emit-buckets`")
    ap.add_argument("--models", default="gcn,sage")
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    buckets = default_buckets()
    spec_path = args.buckets or os.path.join(args.out, "buckets.json")
    if os.path.exists(spec_path):
        extra = load_bucket_specs(spec_path)
        have = {b.name for b in buckets}
        buckets += [b for b in extra if b.name not in have]
        print(f"loaded {len(extra)} bucket specs from {spec_path}")
    compile_all(args.out, buckets, models=args.models.split(","),
                lr=args.lr, force=args.force)


if __name__ == "__main__":
    main()
