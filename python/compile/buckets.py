"""Shape buckets — the static-shape contract between rust (L3) and the
AOT-compiled XLA executables (L2).

XLA programs have static shapes; graphs do not. The rust plan compiler
(``rust/src/hag/schedule``) lowers a graph/HAG into padded index tensors
that fit a *bucket*: a named tuple of every static dimension the lowered
HLO bakes in. ``aot.py`` compiles one artifact per (entry x bucket) and
writes ``artifacts/manifest.json`` so the rust runtime can pick the right
executable and know the exact input/output literal layout.

Conventions (mirrored in rust, see hag::schedule):

* ``n_pad``   — padded node count; multiple of 128 (matmul row tile) and
  of ``br`` x every band's block count.
* ``levels``  — number of HAG topological levels (0 = GNN-graph baseline).
* ``l_pad``   — per-level slot count; multiple of ``lvl_block``.
* ``bands``   — tuple of ``(nb, nnzb)`` for the final block-CSR segment
  sum; sum(nb) * br == n_pad. Multiple bands bound padding waste under
  skewed degree distributions (rust degree-sorts nodes so each band's
  row blocks have similar nnz).
* value buffer size ``m_pad = n_pad + levels * l_pad + 1``; the last slot
  is pinned to zero and is the target of all index padding.
* ``g_pad``   — padded graph count for graph classification (0 = node
  classification). Last graph slot is the padding sink.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class Bucket:
    name: str
    n_pad: int
    f_in: int
    hidden: int
    classes: int
    levels: int
    l_pad: int
    bands: Tuple[Tuple[int, int], ...]   # ((nb, nnzb), ...)
    br: int = 8
    lvl_block: int = 128
    g_pad: int = 0                       # 0 => node classification
    # Band segment-sum implementation:
    #   "mxu"     — Pallas block-CSR kernel (one-hot matmul reduction):
    #               the TPU-shaped path; on the MXU the 8x one-hot FLOP
    #               inflation is free.
    #   "scatter" — XLA scatter-add: work ~ E*F, the right choice on
    #               CPU (12.6x faster at REDDIT band shapes — see
    #               EXPERIMENTS.md §Perf).
    impl: str = "mxu"

    def __post_init__(self):
        assert self.impl in ("mxu", "scatter"), self.impl
        assert self.n_pad % 128 == 0, "n_pad must be a multiple of 128"
        assert sum(nb for nb, _ in self.bands) * self.br == self.n_pad, (
            "bands must tile n_pad exactly")
        if self.levels > 0:
            assert self.l_pad % self.lvl_block == 0, (
                "l_pad must be a multiple of lvl_block")

    @property
    def m_pad(self) -> int:
        return self.n_pad + self.levels * self.l_pad + 1

    @property
    def is_graph_cls(self) -> bool:
        return self.g_pad > 0

    def plan_slots(self) -> int:
        """Total index slots (for memory/padding-waste accounting)."""
        return (self.levels * self.l_pad * 2
                + sum(nb * nnzb for nb, nnzb in self.bands) * 2)

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["bands"] = [list(b) for b in self.bands]
        return d

    @staticmethod
    def from_json(d: dict) -> "Bucket":
        d = dict(d)
        d["bands"] = tuple(tuple(b) for b in d["bands"])
        d.setdefault("impl", "mxu")
        return Bucket(**d)


def load_bucket_specs(path: str):
    """Read a bucket-spec JSON (list of bucket dicts) emitted by
    ``repro emit-buckets`` or hand-written for the default set."""
    with open(path) as f:
        data = json.load(f)
    return [Bucket.from_json(d) for d in data["buckets"]]
