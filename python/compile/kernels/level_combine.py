"""Pallas kernel for one HAG level of binary aggregation nodes.

Every aggregation node created by the search algorithm (Algorithm 3)
combines exactly two operands. The rust scheduler groups nodes into
topological levels; within a level all combines are independent, so the
kernel is a double-gather + vector add over a tile of ``BL`` nodes:

    out[i] = values[left[i]] + values[right[i]]

Padding entries point both indices at the pinned zero slot ``M-1``, so the
result rows for padding are exactly zero. The scatter of ``out`` back into
the value buffer is done by the caller (L2) with a static
``dynamic_update_slice`` — aggregation-node slots are allocated
contiguously per level by the rust scheduler precisely so the scatter is a
dense slice update rather than a random scatter.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _level_combine_kernel(values_ref, left_ref, right_ref, out_ref):
    left = left_ref[...]                      # [BL]
    right = right_ref[...]                    # [BL]
    acc = (values_ref[left].astype(jnp.float32)
           + values_ref[right].astype(jnp.float32))
    out_ref[...] = acc.astype(out_ref.dtype)


def _level_combine_max_kernel(values_ref, left_ref, right_ref, out_ref):
    # Max variant (GraphSAGE-P): operands are >= 0 post-ReLU, so padding
    # (both indices -> pinned zero slot) yields exactly 0.
    acc = jnp.maximum(values_ref[left_ref[...]].astype(jnp.float32),
                      values_ref[right_ref[...]].astype(jnp.float32))
    out_ref[...] = acc.astype(out_ref.dtype)


def _combine_call(kernel, values, left, right, block_len):
    (l,) = left.shape
    m, f = values.shape
    if l % block_len != 0:
        raise ValueError(f"L={l} must be a multiple of block_len={block_len}")
    return pl.pallas_call(
        kernel,
        grid=(l // block_len,),
        in_specs=[
            pl.BlockSpec((m, f), lambda b: (0, 0)),
            pl.BlockSpec((block_len,), lambda b: (b,)),
            pl.BlockSpec((block_len,), lambda b: (b,)),
        ],
        out_specs=pl.BlockSpec((block_len, f), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((l, f), values.dtype),
        interpret=True,
    )(values, left, right)


@functools.partial(jax.jit, static_argnames=("block_len",))
def level_combine(values: jnp.ndarray, left: jnp.ndarray,
                  right: jnp.ndarray, block_len: int = 128) -> jnp.ndarray:
    """values: [M, F] (slot M-1 zero); left/right: [L] int32; -> [L, F]."""
    return _combine_call(_level_combine_kernel, values, left, right,
                         block_len)


@functools.partial(jax.jit, static_argnames=("block_len",))
def level_combine_max(values: jnp.ndarray, left: jnp.ndarray,
                      right: jnp.ndarray,
                      block_len: int = 128) -> jnp.ndarray:
    """Element-wise max combine (GraphSAGE-P); operands must be >= 0."""
    return _combine_call(_level_combine_max_kernel, values, left, right,
                         block_len)
