"""Pure-jnp correctness oracles for the Pallas kernels.

These are the semantic ground truth for every L1 kernel: pytest sweeps
shapes/dtypes (hypothesis) and asserts the Pallas implementations match
these to within dtype tolerance. They are also usable directly by the L2
model (``model.py`` takes ``use_pallas=False``) so the whole AOT pipeline
can be cross-checked kernel-by-kernel.

Conventions shared with the rust plan compiler (``rust/src/hag/schedule``):

* The activation buffer ``values`` has shape ``[M, F]`` where the **last
  slot ``M-1`` is pinned to zero**. All index padding points at it, so
  padded gather contributions vanish under summation without masks.
* Aggregation layouts are *block-CSR*: rows are grouped into blocks of
  ``BR`` rows; each block owns ``NNZB`` index slots. ``blk_col[b, j]``
  indexes into ``values`` (padding -> ``M-1``), ``blk_row[b, j]`` is the
  local destination row in ``0..BR`` (padding may point at any local row —
  it only ever adds zeros).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def block_spmm_ref(values: jnp.ndarray, blk_col: jnp.ndarray,
                   blk_row: jnp.ndarray, block_rows: int) -> jnp.ndarray:
    """Block-CSR sparse-matrix x dense-features segment sum.

    values:  [M, F]   activation buffer (slot M-1 must be zero)
    blk_col: [NB, NNZB] gather indices into values
    blk_row: [NB, NNZB] local destination row within the block (0..BR-1)
    returns: [NB * BR, F] aggregated rows
    """
    nb, nnzb = blk_col.shape
    f = values.shape[1]
    gathered = values[blk_col.reshape(-1)].reshape(nb, nnzb, f)
    # one-hot [NB, NNZB, BR] -> einsum to [NB, BR, F]; f32 accumulation
    onehot = jnp.equal(
        blk_row[:, :, None],
        jnp.arange(block_rows, dtype=blk_row.dtype)[None, None, :],
    ).astype(jnp.float32)
    out = jnp.einsum("bjr,bjf->brf", onehot, gathered.astype(jnp.float32))
    return out.reshape(nb * block_rows, f).astype(values.dtype)


def block_spmm_max_ref(values: jnp.ndarray, blk_col: jnp.ndarray,
                       blk_row: jnp.ndarray, block_rows: int) -> jnp.ndarray:
    """Max-pooling variant of block_spmm_ref (identity 0; operands >= 0)."""
    nb, nnzb = blk_col.shape
    f = values.shape[1]
    gathered = values[blk_col.reshape(-1)].reshape(nb, nnzb, f)
    gathered = gathered.astype(jnp.float32)
    mask = jnp.equal(
        blk_row[:, :, None],
        jnp.arange(block_rows, dtype=blk_row.dtype)[None, None, :],
    )  # [NB, NNZB, BR]
    contrib = jnp.where(mask[:, :, :, None], gathered[:, :, None, :], 0.0)
    out = contrib.max(axis=1)  # [NB, BR, F]
    return out.reshape(nb * block_rows, f).astype(values.dtype)


def level_combine_max_ref(values: jnp.ndarray, left: jnp.ndarray,
                          right: jnp.ndarray) -> jnp.ndarray:
    """Max variant of level_combine_ref."""
    return jnp.maximum(values[left], values[right])


def level_combine_ref(values: jnp.ndarray, left: jnp.ndarray,
                      right: jnp.ndarray) -> jnp.ndarray:
    """One HAG level of binary aggregations.

    values: [M, F]; left/right: [L] indices into values (padding -> M-1).
    returns: [L, F] with out[i] = values[left[i]] + values[right[i]].
    """
    return values[left] + values[right]


def matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Dense matmul with f32 accumulation (MXU semantics)."""
    return jnp.matmul(
        x.astype(jnp.float32), w.astype(jnp.float32)
    ).astype(x.dtype)


def csr_spmm_ref(values, row_ptr, col_idx, n_rows: int) -> jnp.ndarray:
    """Plain CSR segment-sum reference (numpy loop; plan-compiler tests)."""
    values = np.asarray(values)
    rp = np.asarray(row_ptr)
    ci = np.asarray(col_idx)
    out = np.zeros((n_rows, values.shape[1]), dtype=values.dtype)
    for r in range(n_rows):
        sl = ci[rp[r]:rp[r + 1]]
        if len(sl):
            out[r] = values[sl].sum(axis=0)
    return jnp.asarray(out)
