"""L1 Pallas kernels (build-time only; lowered into the L2 HLO).

Exports the three hot-spot kernels plus their pure-jnp oracles:

* ``block_spmm`` — block-CSR segment-sum (the ``hag_aggregate`` operator)
* ``level_combine`` — one HAG level of binary aggregations
* ``tiled_matmul`` — MXU-tiled UPDATE matmul
"""

from .csr_spmm import block_spmm, block_spmm_max
from .level_combine import level_combine, level_combine_max
from .matmul import tiled_matmul
from . import ref

__all__ = [
    "block_spmm", "block_spmm_max",
    "level_combine", "level_combine_max",
    "tiled_matmul", "ref",
]
