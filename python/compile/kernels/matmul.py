"""Pallas tiled matmul — the UPDATE-phase dense kernel.

The GCN/GraphSAGE UPDATE is ``sigma(W . combine(a_v, h_v))``; its matmul is
the dense hot-spot. Tiles are MXU-shaped: ``(BM, BK) @ (BK, BN)`` with f32
accumulation in a VMEM scratch accumulator, K as the innermost grid axis
(classic TPU matmul pipeline: the accumulator stays resident while A/B
tiles stream HBM->VMEM).

GNN hidden dims in the paper's eval are small (16), so tiles clamp to the
actual dims; the kernel is still written in the production K-looped form so
the same BlockSpec scales to large F.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def tiled_matmul(x: jnp.ndarray, w: jnp.ndarray,
                 bm: int = 128, bn: int = 128, bk: int = 128) -> jnp.ndarray:
    """x: [M, K] @ w: [K, N] -> [M, N]; M, K, N divisible by the tile dims
    (clamped to the actual dims when smaller)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    if m % bm or n % bn or k % bk:
        raise ValueError(f"dims {(m, k, n)} not divisible by {(bm, bk, bn)}")
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(x, w)
