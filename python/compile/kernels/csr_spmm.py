"""Pallas block-CSR SpMM — the ``hag_aggregate`` hot-spot kernel.

Computes a segment-sum of gathered feature rows: the sparse-adjacency ×
dense-features product that dominates GNN aggregation (paper §5.1's
``hag_aggregate`` operator). The same kernel executes both the GNN-graph
baseline plan and the final-edge phase of a HAG plan; only the index
tensors differ.

TPU adaptation of the paper's CUDA gathers (DESIGN.md §Hardware-Adaptation):

* rows are tiled into blocks of ``BR`` (the BlockSpec row tile) so each
  output tile is VMEM-resident;
* the per-block reduction is expressed as a one-hot ``[BR, NNZB] @
  [NNZB, F]`` matmul, which maps onto the MXU systolic array instead of
  warp shuffles;
* accumulation is always f32 regardless of activation dtype.

``interpret=True`` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO. The BlockSpec
structure is still what a real-TPU build would use; see DESIGN.md §Perf
for the VMEM/MXU estimates.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _block_spmm_kernel(values_ref, blk_col_ref, blk_row_ref, out_ref,
                       *, block_rows: int):
    cols = blk_col_ref[0]                      # [NNZB] gather indices
    rows = blk_row_ref[0]                      # [NNZB] local dest rows
    gathered = values_ref[cols]                # [NNZB, F] (HBM->VMEM rows)
    onehot = jnp.equal(
        rows[:, None], jnp.arange(block_rows, dtype=rows.dtype)[None, :]
    ).astype(jnp.float32)                      # [NNZB, BR]
    acc = jax.lax.dot_general(
        onehot, gathered.astype(jnp.float32),
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                          # [BR, F] on the MXU
    out_ref[...] = acc.astype(out_ref.dtype)


def _block_spmm_max_kernel(values_ref, blk_col_ref, blk_row_ref, out_ref,
                           *, block_rows: int):
    # Max-pooling variant (GraphSAGE-P). Identity element is 0, which is
    # valid because pooled operands are post-ReLU (>= 0); padding slots
    # gather the pinned zero row and therefore never win the max except
    # when a row has no real operands, in which case the aggregate is 0.
    cols = blk_col_ref[0]
    rows = blk_row_ref[0]
    gathered = values_ref[cols].astype(jnp.float32)    # [NNZB, F]
    mask = jnp.equal(
        rows[:, None], jnp.arange(block_rows, dtype=rows.dtype)[None, :]
    )                                                  # [NNZB, BR]
    # [BR, NNZB, F] masked broadcast, reduce-max over NNZB (VPU reduce)
    contrib = jnp.where(mask.T[:, :, None], gathered[None, :, :], 0.0)
    out_ref[...] = contrib.max(axis=1).astype(out_ref.dtype)


def _spmm_call(kernel, values, blk_col, blk_row, block_rows):
    nb, nnzb = blk_col.shape
    m, f = values.shape
    return pl.pallas_call(
        functools.partial(kernel, block_rows=block_rows),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((m, f), lambda b: (0, 0)),        # full buffer
            pl.BlockSpec((1, nnzb), lambda b: (b, 0)),
            pl.BlockSpec((1, nnzb), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, f), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((nb * block_rows, f), values.dtype),
        interpret=True,
    )(values, blk_col, blk_row)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def block_spmm(values: jnp.ndarray, blk_col: jnp.ndarray,
               blk_row: jnp.ndarray, block_rows: int) -> jnp.ndarray:
    """Block-CSR SpMM (sum); see ref.block_spmm_ref for exact semantics.

    values:  [M, F] activation buffer, slot M-1 pinned to zero
    blk_col: [NB, NNZB] int32 gather indices (padding -> M-1)
    blk_row: [NB, NNZB] int32 local destination row in 0..BR-1
    returns: [NB*BR, F]
    """
    return _spmm_call(_block_spmm_kernel, values, blk_col, blk_row,
                      block_rows)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def block_spmm_max(values: jnp.ndarray, blk_col: jnp.ndarray,
                   blk_row: jnp.ndarray, block_rows: int) -> jnp.ndarray:
    """Block-CSR max-pooling (GraphSAGE-P AGGREGATE); operands must be
    >= 0 (post-ReLU) so the pinned zero slot is a valid identity."""
    return _spmm_call(_block_spmm_max_kernel, values, blk_col, blk_row,
                      block_rows)
