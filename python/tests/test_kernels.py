"""L1 kernel correctness: Pallas implementations vs pure-jnp oracles.

Hypothesis sweeps shapes and dtypes; every property asserts allclose
against ``kernels.ref`` within dtype-appropriate tolerance. These are the
core correctness signal for the AOT pipeline: if these pass, the HLO the
rust runtime executes computes exactly what the paper's Algorithm 2
prescribes (given a valid plan, which rust-side proptests cover).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import block_spmm, level_combine, tiled_matmul, ref

F32 = jnp.float32
BF16 = jnp.bfloat16

TOL = {F32: dict(rtol=1e-5, atol=1e-5), BF16: dict(rtol=2e-2, atol=2e-2)}


def _values(rng, m, f, dtype):
    v = rng.standard_normal((m, f)).astype(np.float32)
    v[-1] = 0.0  # pinned zero slot
    return jnp.asarray(v, dtype=dtype)


# ---------------------------------------------------------------- block_spmm

@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(2, 90),
    f=st.sampled_from([1, 4, 16, 32]),
    nb=st.integers(1, 6),
    nnzb=st.integers(1, 24),
    br=st.sampled_from([1, 4, 8, 16]),
    dtype=st.sampled_from([F32, BF16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_block_spmm_matches_ref(m, f, nb, nnzb, br, dtype, seed):
    rng = np.random.default_rng(seed)
    values = _values(rng, m, f, dtype)
    blk_col = jnp.asarray(rng.integers(0, m, (nb, nnzb)), dtype=jnp.int32)
    blk_row = jnp.asarray(rng.integers(0, br, (nb, nnzb)), dtype=jnp.int32)
    got = block_spmm(values, blk_col, blk_row, br)
    want = ref.block_spmm_ref(values, blk_col, blk_row, br)
    assert got.shape == (nb * br, f)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **TOL[dtype])


def test_block_spmm_padding_rows_are_zero():
    """Slots pointing at the zero slot must contribute exactly zero."""
    rng = np.random.default_rng(7)
    m, f, br = 17, 8, 4
    values = _values(rng, m, f, F32)
    blk_col = jnp.full((2, 6), m - 1, dtype=jnp.int32)   # all padding
    blk_row = jnp.zeros((2, 6), dtype=jnp.int32)
    out = block_spmm(values, blk_col, blk_row, br)
    assert np.all(np.asarray(out) == 0.0)


def test_block_spmm_single_edge_identity():
    """One real edge -> output row equals the gathered value row."""
    rng = np.random.default_rng(8)
    m, f, br = 9, 4, 2
    values = _values(rng, m, f, F32)
    blk_col = jnp.asarray([[3, m - 1, m - 1]], dtype=jnp.int32)
    blk_row = jnp.asarray([[1, 0, 0]], dtype=jnp.int32)
    out = np.asarray(block_spmm(values, blk_col, blk_row, br))
    np.testing.assert_allclose(out[1], np.asarray(values)[3], rtol=1e-6)
    np.testing.assert_allclose(out[0], 0.0)


def test_block_spmm_duplicate_indices_accumulate():
    """The same source gathered twice into one row doubles it."""
    rng = np.random.default_rng(9)
    m, f, br = 9, 4, 2
    values = _values(rng, m, f, F32)
    blk_col = jnp.asarray([[5, 5, m - 1]], dtype=jnp.int32)
    blk_row = jnp.asarray([[0, 0, 1]], dtype=jnp.int32)
    out = np.asarray(block_spmm(values, blk_col, blk_row, br))
    np.testing.assert_allclose(out[0], 2 * np.asarray(values)[5], rtol=1e-6)


# ------------------------------------------------------------- level_combine

@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(2, 200),
    f=st.sampled_from([1, 8, 16, 64]),
    nblocks=st.integers(1, 4),
    block_len=st.sampled_from([8, 32, 128]),
    dtype=st.sampled_from([F32, BF16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_level_combine_matches_ref(m, f, nblocks, block_len, dtype, seed):
    rng = np.random.default_rng(seed)
    values = _values(rng, m, f, dtype)
    length = nblocks * block_len
    left = jnp.asarray(rng.integers(0, m, (length,)), dtype=jnp.int32)
    right = jnp.asarray(rng.integers(0, m, (length,)), dtype=jnp.int32)
    got = level_combine(values, left, right, block_len=block_len)
    want = ref.level_combine_ref(values, left, right)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **TOL[dtype])


def test_level_combine_padding_is_zero():
    rng = np.random.default_rng(3)
    m, f = 12, 8
    values = _values(rng, m, f, F32)
    left = jnp.full((8,), m - 1, dtype=jnp.int32)
    right = jnp.full((8,), m - 1, dtype=jnp.int32)
    out = level_combine(values, left, right, block_len=8)
    assert np.all(np.asarray(out) == 0.0)


def test_level_combine_rejects_ragged_length():
    values = jnp.zeros((4, 2), dtype=F32)
    idx = jnp.zeros((5,), dtype=jnp.int32)
    with pytest.raises(ValueError):
        level_combine(values, idx, idx, block_len=4)


# -------------------------------------------------------------- tiled_matmul

@settings(max_examples=30, deadline=None)
@given(
    mt=st.integers(1, 4),
    kt=st.integers(1, 4),
    nt=st.integers(1, 4),
    tile=st.sampled_from([8, 16, 32]),
    dtype=st.sampled_from([F32, BF16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_tiled_matmul_matches_ref(mt, kt, nt, tile, dtype, seed):
    rng = np.random.default_rng(seed)
    m, k, n = mt * tile, kt * tile, nt * tile
    x = jnp.asarray(rng.standard_normal((m, k)), dtype=dtype)
    w = jnp.asarray(rng.standard_normal((k, n)), dtype=dtype)
    got = tiled_matmul(x, w, bm=tile, bn=tile, bk=tile)
    want = ref.matmul_ref(x, w)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **TOL[dtype])


def test_tiled_matmul_k_accumulation_order():
    """Multiple K tiles must accumulate, not overwrite."""
    m = k = n = 64
    x = jnp.ones((m, k), dtype=F32)
    w = jnp.ones((k, n), dtype=F32)
    out = tiled_matmul(x, w, bm=32, bn=32, bk=16)  # 4 K-steps
    np.testing.assert_allclose(np.asarray(out), float(k))


def test_tiled_matmul_rejects_indivisible():
    x = jnp.zeros((24, 16), dtype=F32)
    w = jnp.zeros((16, 16), dtype=F32)
    with pytest.raises(ValueError):
        tiled_matmul(x, w, bm=16, bn=16, bk=16)
