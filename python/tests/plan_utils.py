"""Test-side plan builder: a minimal python mirror of the rust plan
compiler (``rust/src/hag/schedule``), used to construct valid plan tensors
from explicit adjacency/HAG structure in python tests. Deliberately naive
(single band, no degree sorting) — the production compiler lives in rust;
this exists so the L2 model can be validated independently."""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np
import jax.numpy as jnp

from compile.buckets import Bucket


def dense_adj(adj: Dict[int, Sequence[int]], n: int) -> np.ndarray:
    a = np.zeros((n, n), np.float32)
    for v, ns in adj.items():
        for u in ns:
            a[v, u] = 1.0
    return a


def build_plan(bucket: Bucket, final_edges: Dict[int, Sequence[int]],
               levels: List[List[Tuple[int, int]]] | None = None):
    """Build (lvl_left, lvl_right, band_cols, band_rows) plan tensors.

    final_edges: dest original node -> list of buffer-slot sources
      (original node id, or n_pad + lvl*l_pad + i for aggregation nodes).
    levels: per level, list of (left_slot, right_slot) binary combines;
      combine i of level l writes slot n_pad + l*l_pad + i.
    """
    levels = levels or []
    assert len(levels) == bucket.levels
    zero = bucket.m_pad - 1

    ll = np.full((bucket.levels, bucket.l_pad), zero, np.int32)
    lr = np.full((bucket.levels, bucket.l_pad), zero, np.int32)
    for li, combines in enumerate(levels):
        assert len(combines) <= bucket.l_pad
        for i, (a, b) in enumerate(combines):
            ll[li, i], lr[li, i] = a, b

    assert len(bucket.bands) == 1, "test helper supports a single band"
    nb, nnzb = bucket.bands[0]
    bc = np.full((nb, nnzb), zero, np.int32)
    brw = np.zeros((nb, nnzb), np.int32)
    fill = [0] * nb
    for v, srcs in final_edges.items():
        b, r = divmod(v, bucket.br)
        for u in srcs:
            j = fill[b]
            assert j < nnzb, f"block {b} overflows nnzb={nnzb}"
            bc[b, j], brw[b, j] = u, r
            fill[b] = j + 1
    return (jnp.asarray(ll), jnp.asarray(lr),
            (jnp.asarray(bc),), (jnp.asarray(brw),))


def gnn_graph_plan(bucket: Bucket, adj: Dict[int, Sequence[int]]):
    """Plan for the standard GNN-graph (no aggregation nodes)."""
    assert bucket.levels == 0
    return build_plan(bucket, {v: list(ns) for v, ns in adj.items()})


def degrees(adj: Dict[int, Sequence[int]], n_pad: int) -> jnp.ndarray:
    d = np.zeros((n_pad,), np.float32)
    for v, ns in adj.items():
        d[v] = len(ns)
    return jnp.asarray(d)
