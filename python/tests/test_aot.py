"""AOT pipeline contract tests: entry construction, lowering, manifest
integrity — the python half of the rust<->python interchange."""

import json
import os
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.buckets import Bucket, load_bucket_specs


def tiny_bucket(**kw):
    defaults = dict(name="t", n_pad=128, f_in=8, hidden=16, classes=4,
                    levels=0, l_pad=0, bands=((16, 16),), br=8)
    defaults.update(kw)
    return Bucket(**defaults)


class TestEntryConstruction:
    def test_train_signature_covers_all_sections(self):
        b = tiny_bucket(levels=2, l_pad=128,
                        bands=((8, 32), (8, 16)))
        fn, ispecs, ospecs = aot.build_entry("gcn", "train", b, 0.01)
        names = [s["name"] for s in ispecs]
        # params, opt, data, plan — in that order
        assert names[:4] == ["w1", "b1", "w2", "b2"]
        assert "m_w1" in names and "v_b2" in names
        assert "opt_step" in names
        assert "h0" in names and "labels" in names
        assert "lvl_left" in names and "band1_row" in names
        onames = [s["name"] for s in ospecs]
        assert onames[-2:] == ["loss", "acc"]
        assert len([n for n in onames if n.startswith("new_")]) == 13

    def test_zero_level_bucket_drops_lvl_tensors(self):
        b = tiny_bucket(levels=0, l_pad=0)
        _, ispecs, _ = aot.build_entry("gcn", "infer", b, 0.01)
        names = [s["name"] for s in ispecs]
        assert "lvl_left" not in names
        assert "band0_col" in names

    def test_graph_cls_bucket_has_graph_tensors(self):
        b = tiny_bucket(g_pad=16, classes=2)
        _, ispecs, _ = aot.build_entry("gcn", "train", b, 0.01)
        names = [s["name"] for s in ispecs]
        for t in ["graph_seg", "graph_sizes", "graph_labels",
                  "graph_mask"]:
            assert t in names
        assert "labels" not in names

    def test_entry_executes_with_zero_inputs(self):
        """The flat wrapper must be internally consistent: run it."""
        b = tiny_bucket(levels=1, l_pad=128)
        fn, ispecs, _ = aot.build_entry("gcn", "train", b, 0.01)
        args = []
        for s in ispecs:
            dt = jnp.float32 if s["dtype"] == "f32" else jnp.int32
            if s["dtype"] == "i32" and (s["name"].startswith("lvl_")
                                        or "col" in s["name"]):
                # padding -> zero slot keeps gathers in range
                args.append(jnp.full(s["shape"], b.m_pad - 1, dt))
            else:
                args.append(jnp.zeros(s["shape"], dt))
        outs = fn(*args)
        loss = outs[-2]
        assert np.isfinite(float(loss))

    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError):
            aot.build_entry("gcn", "predict", tiny_bucket(), 0.01)


class TestLowering:
    def test_hlo_text_has_all_parameters(self):
        b = tiny_bucket()
        fn, ispecs, _ = aot.build_entry("gcn", "infer", b, 0.01)
        text = aot.to_hlo_text(fn, ispecs)
        assert text.startswith("HloModule")
        # every flat input must appear as a distinct entry parameter
        # (nested computations also declare parameters; count unique
        # indices instead of raw occurrences)
        import re
        idx = {int(i) for i in re.findall(r"parameter\((\d+)\)", text)}
        assert idx == set(range(len(ispecs)))

    def test_compile_all_writes_manifest_and_caches(self):
        with tempfile.TemporaryDirectory() as d:
            b = tiny_bucket(name="unit0")
            m1 = aot.compile_all(d, [b], models=("gcn",))
            assert len(m1["artifacts"]) == 2  # train + infer
            files = {a["file"] for a in m1["artifacts"]}
            for f in files:
                assert os.path.exists(os.path.join(d, f))
            # second run must be fully cached (identical manifest)
            m2 = aot.compile_all(d, [b], models=("gcn",))
            assert m1 == m2

    def test_manifest_records_shapes(self):
        with tempfile.TemporaryDirectory() as d:
            b = tiny_bucket(name="unit1", levels=1, l_pad=128)
            aot.compile_all(d, [b], models=("gcn",))
            with open(os.path.join(d, "manifest.json")) as f:
                m = json.load(f)
            train = next(a for a in m["artifacts"]
                         if a["kind"] == "train")
            byname = {s["name"]: s for s in train["inputs"]}
            assert byname["h0"]["shape"] == [128, 8]
            assert byname["lvl_left"]["shape"] == [1, 128]
            assert byname["opt_step"]["shape"] == []
            assert byname["opt_step"]["dtype"] == "i32"


class TestBucketSpecs:
    def test_bucket_roundtrip_via_json(self):
        b = tiny_bucket(name="rt", levels=3, l_pad=256,
                        bands=((4, 64), (12, 32)))
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "buckets.json")
            with open(path, "w") as f:
                json.dump({"buckets": [b.to_json()]}, f)
            [b2] = load_bucket_specs(path)
            assert b2 == b

    def test_bucket_validation(self):
        with pytest.raises(AssertionError):
            tiny_bucket(n_pad=100)  # not multiple of 128
        with pytest.raises(AssertionError):
            tiny_bucket(bands=((3, 16),))  # does not tile n_pad
        with pytest.raises(AssertionError):
            tiny_bucket(levels=1, l_pad=100)  # not multiple of block

    def test_plan_slot_accounting(self):
        b = tiny_bucket(levels=2, l_pad=128, bands=((16, 16),))
        assert b.m_pad == 128 + 2 * 128 + 1
        assert b.plan_slots() == 2 * 128 * 2 + 16 * 16 * 2
