"""Gradient correctness for the custom-VJP operator layer (ops.py):
every hand-written backward is checked against (a) finite differences
and (b) jax's AD of the pure-jnp reference implementation."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import ops
from compile.kernels import ref


def numerical_grad(f, x, eps=1e-3):
    """Central finite differences of scalar f at x (f32-friendly eps)."""
    x = np.asarray(x, np.float64)
    g = np.zeros_like(x)
    it = np.nditer(x, flags=["multi_index"])
    while not it.finished:
        i = it.multi_index
        xp = x.copy(); xp[i] += eps
        xm = x.copy(); xm[i] -= eps
        g[i] = (f(jnp.asarray(xp, jnp.float32))
                - f(jnp.asarray(xm, jnp.float32))) / (2 * eps)
        it.iternext()
    return g


@settings(max_examples=10, deadline=None)
@given(m=st.integers(4, 12), f=st.sampled_from([2, 4]),
       seed=st.integers(0, 2**31 - 1))
def test_level_combine_grad_matches_reference(m, f, seed):
    rng = np.random.default_rng(seed)
    values = rng.standard_normal((m, f)).astype(np.float32)
    values[-1] = 0.0
    left = jnp.asarray(rng.integers(0, m, (8,)), jnp.int32)
    right = jnp.asarray(rng.integers(0, m, (8,)), jnp.int32)
    g = rng.standard_normal((8, f)).astype(np.float32)

    def loss_ops(v):
        return jnp.sum(ops.level_combine(v, left, right, 8) * g)

    def loss_ref(v):
        return jnp.sum(ref.level_combine_ref(v, left, right) * g)

    got = jax.grad(loss_ops)(jnp.asarray(values))
    want = jax.grad(loss_ref)(jnp.asarray(values))
    # ops zeroes the pinned slot's cotangent by convention
    want = want.at[m - 1].set(0.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(4, 12), f=st.sampled_from([2, 4]),
       nb=st.integers(1, 3), nnzb=st.integers(1, 6),
       br=st.sampled_from([2, 4]), seed=st.integers(0, 2**31 - 1))
def test_block_spmm_grad_matches_reference(m, f, nb, nnzb, br, seed):
    rng = np.random.default_rng(seed)
    values = rng.standard_normal((m, f)).astype(np.float32)
    values[-1] = 0.0
    bc = jnp.asarray(rng.integers(0, m, (nb, nnzb)), jnp.int32)
    brw = jnp.asarray(rng.integers(0, br, (nb, nnzb)), jnp.int32)
    g = rng.standard_normal((nb * br, f)).astype(np.float32)

    def loss_ops(v):
        return jnp.sum(ops.block_spmm(v, bc, brw, br) * g)

    def loss_ref(v):
        return jnp.sum(ref.block_spmm_ref(v, bc, brw, br) * g)

    got = jax.grad(loss_ops)(jnp.asarray(values))
    want = jax.grad(loss_ref)(jnp.asarray(values))
    want = want.at[m - 1].set(0.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5)


def test_matmul_grad_finite_difference():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((8, 8)).astype(np.float32)
    w = rng.standard_normal((8, 8)).astype(np.float32)
    gx = jax.grad(lambda a: jnp.sum(jnp.tanh(
        ops.matmul(a, jnp.asarray(w), 8, 8, 8))))(jnp.asarray(x))
    num = numerical_grad(
        lambda a: float(jnp.sum(jnp.tanh(
            ops.matmul(a, jnp.asarray(w), 8, 8, 8)))), x)
    np.testing.assert_allclose(np.asarray(gx), num, atol=5e-2)


def test_block_spmm_max_grad_routes_to_argmax():
    # two candidates for row 0; gradient must flow to the larger one
    m, f, br = 5, 2, 2
    values = np.zeros((m, f), np.float32)
    values[1] = [3.0, -1.0]
    values[2] = [1.0, 5.0]
    bc = jnp.asarray([[1, 2, m - 1]], jnp.int32)
    brw = jnp.asarray([[0, 0, 1]], jnp.int32)

    def loss(v):
        out = ops.block_spmm_max(v, bc, brw, br)
        return out[0, 0] * 2.0 + out[0, 1] * 3.0

    g = np.asarray(jax.grad(loss)(jnp.asarray(values)))
    # feature 0 max is values[1], feature 1 max is values[2]
    assert g[1, 0] == 2.0 and g[1, 1] == 0.0
    assert g[2, 0] == 0.0 and g[2, 1] == 3.0


def test_level_combine_max_grad_ties_split():
    m, f = 4, 1
    values = np.array([[2.0], [2.0], [0.0], [0.0]], np.float32)
    left = jnp.asarray([0], jnp.int32)
    right = jnp.asarray([1], jnp.int32)

    def loss(v):
        return jnp.sum(ops.level_combine_max(v, left, right, 1))

    g = np.asarray(jax.grad(loss)(jnp.asarray(values)))
    # tie: both achievers receive the cotangent (subgradient convention)
    assert g[0, 0] == 1.0 and g[1, 0] == 1.0
