"""L2 model correctness: aggregation semantics, HAG == GNN-graph
equivalence (Theorem 1 at the numerics level), gradients, training step,
and both model families from Table 1."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.buckets import Bucket
from compile import model as M

from .plan_utils import build_plan, gnn_graph_plan, dense_adj, degrees

BR = 8


def tiny_bucket(levels=0, l_pad=0, g_pad=0, f_in=8, hidden=16, classes=4,
                nnzb=16):
    return Bucket(name="t", n_pad=128, f_in=f_in, hidden=hidden,
                  classes=classes, levels=levels, l_pad=l_pad,
                  bands=((128 // BR, nnzb),), br=BR, g_pad=g_pad)


RNG = np.random.default_rng(42)
ADJ = {0: [1, 2, 3], 1: [0, 2], 2: [0, 1, 4], 3: [1, 2], 4: [1, 2]}


def feats(bucket, n_real=5, seed=0):
    rng = np.random.default_rng(seed)
    h = np.zeros((bucket.n_pad, bucket.f_in), np.float32)
    h[:n_real] = rng.standard_normal((n_real, bucket.f_in))
    return jnp.asarray(h)


class TestAggregationSemantics:
    def test_gnn_graph_plan_matches_dense(self):
        b = tiny_bucket()
        h = feats(b)
        plan = gnn_graph_plan(b, ADJ)
        agg = M.hag_aggregate_sum(h, *plan[:2], plan[2], plan[3], b)
        want = dense_adj(ADJ, b.n_pad) @ np.asarray(h)
        np.testing.assert_allclose(np.asarray(agg), want, atol=1e-5)

    def test_hag_plan_equivalent_to_gnn_graph(self):
        """Paper Fig 1: HAG with shared {1,2} aggregation node produces
        identical aggregates to the flat GNN-graph."""
        b0 = tiny_bucket(levels=0)
        bh = tiny_bucket(levels=1, l_pad=128)
        h = feats(b0)
        w = bh.n_pad  # slot of the single aggregation node
        flat = gnn_graph_plan(b0, ADJ)
        hag = build_plan(
            bh,
            {0: [w, 3], 1: [0, 2], 2: [0, 1, 4], 3: [w], 4: [w]},
            levels=[[(1, 2)]],
        )
        a_flat = M.hag_aggregate_sum(h, *flat[:2], flat[2], flat[3], b0)
        a_hag = M.hag_aggregate_sum(h, *hag[:2], hag[2], hag[3], bh)
        np.testing.assert_allclose(np.asarray(a_flat), np.asarray(a_hag),
                                   atol=1e-5)

    def test_multi_level_hag(self):
        """Two-level hierarchy: w2 = (w1 + node) must chain correctly."""
        b = tiny_bucket(levels=2, l_pad=128)
        h = feats(b)
        w1 = b.n_pad            # level-0 slot 0: {1,2}
        w2 = b.n_pad + b.l_pad  # level-1 slot 0: {w1, 3} = {1,2,3}
        adj = {0: [1, 2, 3], 3: [1, 2, 3], 4: [1, 2]}
        plan = build_plan(b, {0: [w2], 3: [w2], 4: [w1]},
                          levels=[[(1, 2)], [(w1, 3)]])
        agg = M.hag_aggregate_sum(h, *plan[:2], plan[2], plan[3], b)
        want = dense_adj(adj, b.n_pad) @ np.asarray(h)
        np.testing.assert_allclose(np.asarray(agg), want, atol=1e-5)

    def test_transpose_grad_matches_dense_transpose(self):
        b = tiny_bucket(levels=1, l_pad=128)
        h = feats(b)
        w = b.n_pad
        plan = build_plan(
            b, {0: [w, 3], 1: [0, 2], 2: [0, 1, 4], 3: [w], 4: [w]},
            levels=[[(1, 2)]])
        g = RNG.standard_normal((b.n_pad, b.f_in)).astype(np.float32)

        def f(x):
            return jnp.sum(
                M.hag_aggregate_sum(x, *plan[:2], plan[2], plan[3], b)
                * g)

        dh = jax.grad(f)(h)
        want = dense_adj(ADJ, b.n_pad).T @ g
        np.testing.assert_allclose(np.asarray(dh), want, atol=1e-4)

    def test_max_aggregate_matches_dense_max(self):
        b = tiny_bucket()
        rng = np.random.default_rng(3)
        h = np.zeros((b.n_pad, b.f_in), np.float32)
        h[:5] = np.abs(rng.standard_normal((5, b.f_in)))  # >= 0 domain
        plan = gnn_graph_plan(b, ADJ)
        agg = M.hag_aggregate_max(jnp.asarray(h), *plan[:2], plan[2],
                                  plan[3], b)
        want = np.zeros_like(h)
        for v, ns in ADJ.items():
            want[v] = h[list(ns)].max(axis=0)
        np.testing.assert_allclose(np.asarray(agg), want, atol=1e-5)

    def test_empty_neighborhood_aggregates_to_zero(self):
        b = tiny_bucket()
        h = feats(b)
        plan = gnn_graph_plan(b, {0: [1]})  # only node 0 has neighbors
        agg = np.asarray(
            M.hag_aggregate_sum(h, *plan[:2], plan[2], plan[3], b))
        assert np.all(agg[1:] == 0.0)


class TestGCN:
    def test_forward_shapes_and_padding(self):
        b = tiny_bucket()
        params = M.init_gcn_params(b)
        h = feats(b)
        plan = gnn_graph_plan(b, ADJ)
        logits = M.gcn_forward(params, h, degrees(ADJ, b.n_pad), plan, b)
        assert logits.shape == (b.n_pad, b.classes)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_gcn_equivalence_gnn_graph_vs_hag(self):
        """End-to-end Theorem 1: same logits through the full 2-layer
        model under both representations."""
        b0 = tiny_bucket(levels=0)
        bh = tiny_bucket(levels=1, l_pad=128)
        params = M.init_gcn_params(b0)
        h = feats(b0)
        deg = degrees(ADJ, b0.n_pad)
        w = bh.n_pad
        flat = gnn_graph_plan(b0, ADJ)
        hag = build_plan(
            bh, {0: [w, 3], 1: [0, 2], 2: [0, 1, 4], 3: [w], 4: [w]},
            levels=[[(1, 2)]])
        l0 = M.gcn_forward(params, h, deg, flat, b0)
        l1 = M.gcn_forward(params, h, deg, hag, bh)
        np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                                   atol=1e-5)

    def test_train_step_decreases_loss(self):
        b = tiny_bucket()
        params = M.init_gcn_params(b)
        opt = M.init_opt_state(params)
        h = feats(b)
        deg = degrees(ADJ, b.n_pad)
        plan = gnn_graph_plan(b, ADJ)
        labels = jnp.asarray(
            np.array([0, 1, 2, 3, 0] + [0] * (b.n_pad - 5), np.int32))
        mask = jnp.asarray(
            np.array([1.0] * 5 + [0.0] * (b.n_pad - 5), np.float32))
        step = jax.jit(M.make_node_train_step(b, M.gcn_forward, lr=0.05))
        losses = []
        for _ in range(20):
            params, opt, loss, acc = step(params, opt, h, deg, labels,
                                          mask, *plan)
            losses.append(float(loss))
        assert losses[-1] < losses[0] * 0.85, losses
        assert int(opt["step"]) == 20

    def test_gradients_equal_under_equivalent_plans(self):
        """Equivalence condition (2): same parameter gradients."""
        b0 = tiny_bucket(levels=0)
        bh = tiny_bucket(levels=1, l_pad=128)
        params = M.init_gcn_params(b0)
        h = feats(b0)
        deg = degrees(ADJ, b0.n_pad)
        labels = jnp.zeros((b0.n_pad,), jnp.int32)
        mask = jnp.asarray(np.array([1.0] * 5 + [0.0] * (b0.n_pad - 5),
                                    np.float32))
        w = bh.n_pad
        flat = gnn_graph_plan(b0, ADJ)
        hag = build_plan(
            bh, {0: [w, 3], 1: [0, 2], 2: [0, 1, 4], 3: [w], 4: [w]},
            levels=[[(1, 2)]])

        def loss(p, plan, bb):
            logits = M.gcn_forward(p, h, deg, plan, bb)
            return M.masked_softmax_ce(logits, labels, mask)

        g0 = jax.grad(loss)(params, flat, b0)
        g1 = jax.grad(loss)(params, hag, bh)
        for k in g0:
            np.testing.assert_allclose(np.asarray(g0[k]),
                                       np.asarray(g1[k]), atol=1e-5,
                                       err_msg=k)


class TestSage:
    def test_forward_shapes(self):
        b = tiny_bucket()
        params = M.init_sage_params(b)
        h = feats(b)
        plan = gnn_graph_plan(b, ADJ)
        out = M.sage_forward(params, h, degrees(ADJ, b.n_pad), plan, b)
        assert out.shape == (b.n_pad, b.classes)
        assert bool(jnp.all(jnp.isfinite(out)))

    def test_sage_equivalence_gnn_graph_vs_hag(self):
        """Max-pooling HAG must also satisfy Theorem 1 (max is
        associative + commutative)."""
        b0 = tiny_bucket(levels=0)
        bh = tiny_bucket(levels=1, l_pad=128)
        params = M.init_sage_params(b0)
        h = feats(b0)
        deg = degrees(ADJ, b0.n_pad)
        w = bh.n_pad
        flat = gnn_graph_plan(b0, ADJ)
        hag = build_plan(
            bh, {0: [w, 3], 1: [0, 2], 2: [0, 1, 4], 3: [w], 4: [w]},
            levels=[[(1, 2)]])
        l0 = M.sage_forward(params, h, deg, flat, b0)
        l1 = M.sage_forward(params, h, deg, hag, bh)
        np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                                   atol=1e-5)

    def test_sage_train_step_runs(self):
        b = tiny_bucket()
        params = M.init_sage_params(b)
        opt = M.init_opt_state(params)
        h = feats(b)
        deg = degrees(ADJ, b.n_pad)
        plan = gnn_graph_plan(b, ADJ)
        labels = jnp.zeros((b.n_pad,), jnp.int32)
        mask = jnp.asarray(np.array([1.0] * 5 + [0.0] * (b.n_pad - 5),
                                    np.float32))
        step = jax.jit(M.make_node_train_step(b, M.sage_forward, lr=0.05))
        losses = []
        p, o = params, opt
        for _ in range(10):
            p, o, loss, _ = step(p, o, h, deg, labels, mask, *plan)
            losses.append(float(loss))
        assert losses[-1] < losses[0], losses


class TestGraphClassification:
    def test_graph_pool_mean(self):
        g_pad = 16
        h = np.zeros((128, 4), np.float32)
        h[0], h[1], h[2] = 1.0, 3.0, 10.0
        seg = np.full((128,), g_pad - 1, np.int32)
        seg[0] = seg[1] = 0
        seg[2] = 1
        sizes = np.ones((g_pad,), np.float32)
        sizes[0], sizes[1] = 2.0, 1.0
        pooled = M.graph_pool(jnp.asarray(h), jnp.asarray(seg),
                              jnp.asarray(sizes), g_pad)
        np.testing.assert_allclose(np.asarray(pooled)[0], 2.0, atol=1e-6)
        np.testing.assert_allclose(np.asarray(pooled)[1], 10.0, atol=1e-6)

    def test_graph_train_step_decreases_loss(self):
        b = tiny_bucket(levels=0, g_pad=16, classes=2, nnzb=32)
        params = M.init_gcn_params(b)
        opt = M.init_opt_state(params)
        # two graphs of 4 nodes each: ring vs clique-ish
        adj = {0: [1, 3], 1: [0, 2], 2: [1, 3], 3: [2, 0],
               4: [5, 6, 7], 5: [4, 6, 7], 6: [4, 5, 7], 7: [4, 5, 6]}
        rng = np.random.default_rng(0)
        h = np.zeros((b.n_pad, b.f_in), np.float32)
        h[:8] = rng.standard_normal((8, b.f_in))
        plan = gnn_graph_plan(b, adj)
        seg = np.full((b.n_pad,), b.g_pad - 1, np.int32)
        seg[:4] = 0
        seg[4:8] = 1
        sizes = np.ones((b.g_pad,), np.float32)
        sizes[0] = sizes[1] = 4.0
        glabels = np.zeros((b.g_pad,), np.int32)
        glabels[1] = 1
        gmask = np.zeros((b.g_pad,), np.float32)
        gmask[:2] = 1.0
        step = jax.jit(M.make_graph_train_step(b, M.gcn_forward, lr=0.05))
        p, o = params, opt
        losses = []
        for _ in range(15):
            p, o, loss, acc = step(
                p, o, jnp.asarray(h), degrees(adj, b.n_pad),
                jnp.asarray(seg), jnp.asarray(sizes),
                jnp.asarray(glabels), jnp.asarray(gmask), *plan)
            losses.append(float(loss))
        assert losses[-1] < losses[0], losses
